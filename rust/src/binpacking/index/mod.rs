//! The indexed packing engine — `O(log m)` placement for the whole
//! Any-Fit family, plus the incremental state the IRM's hot loop needs.
//!
//! | rule | index | select cost | structure |
//! |---|---|---|---|
//! | First-Fit | max-residual segment tree, leftmost-fit descent | `O(log m)` | [`ResidualTree`] |
//! | Next-Fit | open-bin cursor | `O(1)` | `usize` |
//! | Best-Fit | ordered residual map (successor query) | `O(log m)` | [`ResidualMap`] |
//! | Worst-Fit | max-residual segment tree, leftmost-max descent | `O(log m)` | [`ResidualTree`] |
//! | Harmonic(k) | per-class open-bin buckets + free-bin pool | `O(1)` (`O(log m)` on open) | [`HarmonicBuckets`] |
//!
//! [`PackEngine`] owns the bins *and* the rule's index and keeps both in
//! sync across insertions, so a long-lived caller (the IRM allocator, the
//! simulator) pays `O(log m)` per scheduling decision instead of the
//! `O(m)` scan — and, via [`PackEngine::sync_used`], reuses all of its
//! allocations between control cycles instead of rebuilding `Vec<Bin>`
//! every tick.
//!
//! Placement decisions are **identical** to the naive reference scans in
//! [`algorithms`](crate::binpacking::algorithms) (ties always break toward
//! the lowest bin index); `rust/tests/binpacking_equivalence.rs` proves it
//! property-wise over random item streams and pre-populated bins.
//!
//! The **multi-dimensional** counterpart is [`VecPackEngine`]: the whole
//! vector Any-Fit family plus Harmonic
//! ([`VecRule`](crate::binpacking::multidim::VecRule)) over CPU/RAM/net
//! with heterogeneous (VM-flavor) bin capacities — one residual tree per
//! dimension, candidate walk keyed on the item's dominant dimension, full
//! fit check across all dimensions
//! (`rust/tests/binpacking_multidim_equivalence.rs` proves every rule
//! against its naive oracle in
//! [`multidim`](crate::binpacking::multidim)).

mod harmonic_buckets;
mod residual_map;
mod residual_tree;
mod vec_engine;

pub use harmonic_buckets::HarmonicBuckets;
pub use residual_map::ResidualMap;
pub use residual_tree::ResidualTree;
pub use vec_engine::{first_fit_md_indexed, pack_md_indexed, VecPackEngine};

use super::algorithms::{any_fit_insert, harmonic_insert, AnyFit};
use super::{Bin, BinPacker, Item, Packing};

/// Which packing rule an engine (or [`IndexedPacker`]) runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineRule {
    First,
    Next,
    Best,
    Worst,
    /// Harmonic with `k` classes.
    Harmonic(usize),
}

/// The rule-specific index (each variant carries exactly the structure its
/// rule needs — see the module-level table).
#[derive(Clone, Debug)]
enum RuleIndex {
    First(ResidualTree),
    Next { cursor: usize },
    Best(ResidualMap),
    Worst(ResidualTree),
    Harmonic(HarmonicBuckets),
}

/// A stateful, indexed bin-packer: bins plus the rule index, kept
/// consistent across [`insert`](PackEngine::insert) calls.
#[derive(Clone, Debug)]
pub struct PackEngine {
    rule: EngineRule,
    bins: Vec<Bin>,
    index: RuleIndex,
}

impl PackEngine {
    /// Build an engine over `initial` bins (possibly pre-loaded). Matches
    /// batch semantics: Harmonic treats pre-existing bins as closed.
    pub fn new(rule: EngineRule, initial: Vec<Bin>) -> PackEngine {
        let index = match rule {
            EngineRule::First | EngineRule::Worst => {
                let mut tree = ResidualTree::new(initial.len().max(16));
                for (i, b) in initial.iter().enumerate() {
                    tree.set(i, b.residual());
                }
                if rule == EngineRule::First {
                    RuleIndex::First(tree)
                } else {
                    RuleIndex::Worst(tree)
                }
            }
            EngineRule::Next => RuleIndex::Next {
                cursor: initial.len().saturating_sub(1),
            },
            EngineRule::Best => {
                let mut map = ResidualMap::new();
                for b in &initial {
                    map.push(b.residual());
                }
                RuleIndex::Best(map)
            }
            EngineRule::Harmonic(k) => {
                let mut buckets = HarmonicBuckets::new(k);
                for (i, b) in initial.iter().enumerate() {
                    if b.used <= super::EPS && b.items.is_empty() {
                        buckets.add_free(i);
                    }
                }
                RuleIndex::Harmonic(buckets)
            }
        };
        PackEngine {
            rule,
            bins: initial,
            index,
        }
    }

    pub fn rule(&self) -> EngineRule {
        self.rule
    }

    pub fn bins(&self) -> &[Bin] {
        &self.bins
    }

    pub fn len(&self) -> usize {
        self.bins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bins.is_empty()
    }

    /// Consume the engine, returning its bins.
    pub fn into_bins(self) -> Vec<Bin> {
        self.bins
    }

    /// Place one item, returning its bin index — `O(log m)`.
    pub fn insert(&mut self, item: Item) -> usize {
        let chosen = match &mut self.index {
            RuleIndex::First(tree) => tree.first_fit(item.size),
            RuleIndex::Worst(tree) => tree.worst_fit(item.size),
            RuleIndex::Best(map) => map.best_fit(item.size),
            RuleIndex::Next { cursor } => {
                let c = *cursor;
                if c < self.bins.len() && self.bins[c].fits(&item) {
                    Some(c)
                } else {
                    None
                }
            }
            RuleIndex::Harmonic(buckets) => {
                let class = buckets.class_of(item.size);
                match buckets.open(class) {
                    // A class-j bin holds at most j items; float dust can
                    // also close it early, exactly like the naive packer.
                    Some((idx, count)) if count < class && self.bins[idx].fits(&item) => {
                        buckets.bump(class);
                        Some(idx)
                    }
                    _ => None,
                }
            }
        };
        let idx = match chosen {
            Some(idx) => idx,
            None => {
                // Any-Fit invariant: open a new bin only when nothing
                // fits. Harmonic first claims the lowest-index *empty*
                // pre-existing bin (an idle worker is class-pure).
                let reused = match &mut self.index {
                    RuleIndex::Harmonic(buckets) => buckets.take_free(),
                    _ => None,
                };
                let idx = match reused {
                    Some(idx) => idx,
                    None => {
                        self.bins.push(Bin::new());
                        self.bins.len() - 1
                    }
                };
                match &mut self.index {
                    RuleIndex::First(tree) | RuleIndex::Worst(tree) => {
                        tree.set(idx, self.bins[idx].residual());
                    }
                    RuleIndex::Best(map) => {
                        if idx == map.len() {
                            map.push(1.0);
                        }
                    }
                    RuleIndex::Next { cursor } => *cursor = idx,
                    RuleIndex::Harmonic(buckets) => {
                        let class = buckets.class_of(item.size);
                        buckets.open_new(class, idx);
                    }
                }
                idx
            }
        };
        self.bins[idx].push(item);
        match &mut self.index {
            RuleIndex::First(tree) | RuleIndex::Worst(tree) => {
                tree.set(idx, self.bins[idx].residual());
            }
            RuleIndex::Best(map) => map.set(idx, self.bins[idx].residual()),
            RuleIndex::Next { .. } | RuleIndex::Harmonic(_) => {}
        }
        idx
    }

    /// Pack a whole item sequence, consuming the engine.
    pub fn pack_all(mut self, items: &[Item]) -> Packing {
        let mut assignments = Vec::with_capacity(items.len());
        for item in items {
            assignments.push(self.insert(*item));
        }
        Packing {
            assignments,
            bins: self.bins,
        }
    }

    /// Reconcile the engine to an externally observed bin population: bin
    /// `i` gets load `used[i]` (clamped to `[0, 1]`), bins beyond are
    /// dropped. This is the IRM's per-cycle entry point: all storage is
    /// reused, only *changed* loads touch the index, and the per-bin item
    /// lists are cleared (their capacity kept) — placement-equivalent to
    /// rebuilding a fresh engine over `Bin::with_used` bins, without the
    /// allocations.
    pub fn sync_used<I>(&mut self, used: I)
    where
        I: IntoIterator<Item = f64>,
        I::IntoIter: ExactSizeIterator,
    {
        let used = used.into_iter();
        let n = used.len();
        if self.bins.len() > n {
            match &mut self.index {
                RuleIndex::First(tree) | RuleIndex::Worst(tree) => tree.truncate(n),
                RuleIndex::Best(map) => map.truncate(n),
                RuleIndex::Next { .. } | RuleIndex::Harmonic(_) => {}
            }
            self.bins.truncate(n);
        }
        for (i, u) in used.enumerate() {
            let u = u.clamp(0.0, 1.0);
            if i < self.bins.len() {
                let bin = &mut self.bins[i];
                bin.items.clear();
                if bin.used != u {
                    bin.used = u;
                    match &mut self.index {
                        RuleIndex::First(tree) | RuleIndex::Worst(tree) => {
                            tree.set(i, bin.residual());
                        }
                        RuleIndex::Best(map) => map.set(i, bin.residual()),
                        RuleIndex::Next { .. } | RuleIndex::Harmonic(_) => {}
                    }
                }
            } else {
                let bin = Bin::with_used(u);
                match &mut self.index {
                    RuleIndex::First(tree) | RuleIndex::Worst(tree) => {
                        tree.set(i, bin.residual());
                    }
                    RuleIndex::Best(map) => map.push(bin.residual()),
                    RuleIndex::Next { .. } | RuleIndex::Harmonic(_) => {}
                }
                self.bins.push(bin);
            }
        }
        // Rule state resets to batch-start semantics over the new view
        // (for Harmonic that includes re-offering the now-empty bins —
        // idle workers — as claimable class bins).
        match &mut self.index {
            RuleIndex::Next { cursor } => *cursor = n.saturating_sub(1),
            RuleIndex::Harmonic(buckets) => {
                buckets.clear();
                for (i, b) in self.bins.iter().enumerate() {
                    if b.used <= super::EPS && b.items.is_empty() {
                        buckets.add_free(i);
                    }
                }
            }
            _ => {}
        }
    }
}

/// Engine-backed [`BinPacker`]: drop-in indexed replacement for the naive
/// scans, placement-identical (property-tested) but `O(n log m)` per batch
/// instead of `O(n·m)`.
#[derive(Clone, Copy, Debug)]
pub struct IndexedPacker {
    rule: EngineRule,
}

impl IndexedPacker {
    pub fn new(rule: EngineRule) -> Self {
        IndexedPacker { rule }
    }

    pub fn first() -> Self {
        Self::new(EngineRule::First)
    }

    pub fn next() -> Self {
        Self::new(EngineRule::Next)
    }

    pub fn best() -> Self {
        Self::new(EngineRule::Best)
    }

    pub fn worst() -> Self {
        Self::new(EngineRule::Worst)
    }

    pub fn harmonic(k: usize) -> Self {
        Self::new(EngineRule::Harmonic(k))
    }

    pub fn rule(&self) -> EngineRule {
        self.rule
    }

    /// A live engine over `initial` bins — for callers that keep inserting.
    pub fn engine(&self, initial: Vec<Bin>) -> PackEngine {
        PackEngine::new(self.rule, initial)
    }
}

impl BinPacker for IndexedPacker {
    fn name(&self) -> &'static str {
        match self.rule {
            EngineRule::First => "first-fit-indexed",
            EngineRule::Next => "next-fit-indexed",
            EngineRule::Best => "best-fit-indexed",
            EngineRule::Worst => "worst-fit-indexed",
            EngineRule::Harmonic(_) => "harmonic-k-indexed",
        }
    }

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
        PackEngine::new(self.rule, initial).pack_all(items)
    }

    /// Single insertion into caller-owned bins: the `O(m)` in-place scan
    /// (no engine rebuild, no reallocation — for `O(log m)` repeated
    /// insertion hold a [`PackEngine`] instead).
    fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize {
        match self.rule {
            EngineRule::First => any_fit_insert(AnyFit::First, bins, item),
            EngineRule::Next => any_fit_insert(AnyFit::Next, bins, item),
            EngineRule::Best => any_fit_insert(AnyFit::Best, bins, item),
            EngineRule::Worst => any_fit_insert(AnyFit::Worst, bins, item),
            EngineRule::Harmonic(k) => harmonic_insert(k, bins, item),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::{BestFit, FirstFit, WorstFit};

    fn items(sizes: &[f64]) -> Vec<Item> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| Item::new(i as u64, s))
            .collect()
    }

    #[test]
    fn engine_first_matches_naive_on_textbook_sequence() {
        let its = items(&[0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6]);
        let naive = FirstFit.pack(&its, Vec::new());
        let engine = IndexedPacker::first().pack(&its, Vec::new());
        assert_eq!(naive.assignments, engine.assignments);
    }

    #[test]
    fn engine_best_picks_tightest() {
        let initial = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        let p = IndexedPacker::best().pack(&items(&[0.3]), initial);
        assert_eq!(p.assignments[0], 0);
    }

    #[test]
    fn engine_worst_picks_emptiest() {
        let initial = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        let p = IndexedPacker::worst().pack(&items(&[0.3]), initial);
        assert_eq!(p.assignments[0], 1);
    }

    #[test]
    fn engine_harmonic_keeps_classes_apart() {
        let its = items(&[0.6, 0.35, 0.34, 0.2, 0.19, 0.18]);
        let p = IndexedPacker::harmonic(4).pack(&its, Vec::new());
        p.check(&its).unwrap();
        assert_eq!(p.assignments[1], p.assignments[2]);
        assert_ne!(p.assignments[0], p.assignments[1]);
    }

    #[test]
    fn incremental_insert_is_stateful() {
        // The engine keeps Harmonic's open bins across inserts — the very
        // thing the old pack_one lost.
        let mut e = PackEngine::new(EngineRule::Harmonic(4), Vec::new());
        let a = e.insert(Item::new(0, 0.35));
        let b = e.insert(Item::new(1, 0.34));
        assert_eq!(a, b, "same class-2 bin across separate inserts");
    }

    #[test]
    fn sync_used_matches_fresh_engine() {
        let loads = [0.8, 0.2, 0.55];
        let its = items(&[0.4, 0.3, 0.1, 0.25]);
        for rule in [
            EngineRule::First,
            EngineRule::Next,
            EngineRule::Best,
            EngineRule::Worst,
            EngineRule::Harmonic(7),
        ] {
            // A dirty engine (leftover bins from a previous round) synced
            // to `loads` must place exactly like a fresh engine.
            let mut dirty = PackEngine::new(rule, Vec::new());
            for it in &items(&[0.9, 0.9, 0.9, 0.9, 0.9]) {
                dirty.insert(*it);
            }
            dirty.sync_used(loads.iter().copied());
            let fresh = PackEngine::new(
                rule,
                loads.iter().map(|&u| Bin::with_used(u)).collect(),
            );
            let got: Vec<usize> = {
                let mut d = dirty.clone();
                its.iter().map(|it| d.insert(*it)).collect()
            };
            let want = fresh.pack_all(&its).assignments;
            assert_eq!(got, want, "rule {rule:?}");
        }
    }

    #[test]
    fn pack_one_uses_rule_scan() {
        let mut bins = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        assert_eq!(IndexedPacker::best().pack_one(Item::new(0, 0.3), &mut bins), 0);
        let mut bins = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        assert_eq!(
            IndexedPacker::worst().pack_one(Item::new(0, 0.3), &mut bins),
            1
        );
        // Naive scans agree.
        let mut bins = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        assert_eq!(BestFit.pack_one(Item::new(0, 0.3), &mut bins), 0);
        let mut bins = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        assert_eq!(WorstFit.pack_one(Item::new(0, 0.3), &mut bins), 1);
    }
}
