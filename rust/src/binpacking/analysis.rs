//! Packing-quality analysis: the paper's "ideal number of bins" (Fig 10's
//! *active bins* lower bound is `ceil(Σ item sizes)`) and asymptotic-ratio
//! estimation used by the algorithm ablation (DESIGN.md A1).

use super::multidim::{ideal_bins_md, VecItem, VecPacking, DIMS};
use super::{BinPacker, Item, Packing, EPS};

/// Lower bound on the optimal number of unit bins: `ceil(Σ sizes)`.
pub fn ideal_bins(items: &[Item]) -> usize {
    let total: f64 = items.iter().map(|i| i.size).sum();
    // Tolerate float dust (e.g. ten 0.1-items must be 1 bin, not 2).
    crate::util::cast::f64_to_usize((total - EPS).ceil().max(0.0))
}

/// `bins_used / ideal` — an (over)estimate of the performance ratio R for
/// one instance (R is asymptotic; we report the empirical instance ratio).
pub fn performance_ratio(packing: &Packing, items: &[Item]) -> f64 {
    let ideal = ideal_bins(items).max(1);
    packing.bins_used() as f64 / ideal as f64
}

/// Summary statistics for one packing.
#[derive(Clone, Debug, PartialEq)]
pub struct PackingStats {
    pub bins_used: usize,
    pub ideal_bins: usize,
    pub ratio: f64,
    /// Mean load of non-empty bins (utilization; the paper's Figs 4/8 show
    /// workers peaking at 90–100 %).
    pub mean_load: f64,
    /// Total unused capacity across non-empty bins.
    pub waste: f64,
}

pub fn stats(packing: &Packing, items: &[Item]) -> PackingStats {
    let used: Vec<f64> = packing
        .bins
        .iter()
        .filter(|b| b.used > EPS)
        .map(|b| b.used)
        .collect();
    let bins_used = used.len();
    let mean_load = if bins_used == 0 {
        0.0
    } else {
        used.iter().sum::<f64>() / bins_used as f64
    };
    let waste = used.iter().map(|u| (1.0 - u).max(0.0)).sum();
    PackingStats {
        bins_used,
        ideal_bins: ideal_bins(items),
        ratio: performance_ratio(packing, items),
        mean_load,
        waste,
    }
}

/// Summary statistics for one multi-dimensional packing.
#[derive(Clone, Debug, PartialEq)]
pub struct VecPackingStats {
    pub bins_used: usize,
    /// Unit-capacity lower bound (`max_d ceil(Σ size_d)`).
    pub ideal_bins: usize,
    /// `bins_used / ideal_bins` (empirical instance ratio).
    pub ratio: f64,
    /// Mean per-dimension load of non-empty bins, as a fraction of each
    /// bin's own capacity.
    pub mean_load: [f64; DIMS],
    /// Worst per-dimension *overcommit* across bins: `max_i (used_d −
    /// cap_d)`, zero when every bin respects its capacity. Non-zero only
    /// for packings produced by a capacity-blind (CPU-only) model — the
    /// quantity the multi-dim ablation reports.
    pub overcommit: [f64; DIMS],
}

/// Stats for a vector packing (the multi-dim ablation's table rows).
pub fn stats_md(packing: &VecPacking, items: &[VecItem]) -> VecPackingStats {
    let mut mean_load = [0.0f64; DIMS];
    let mut overcommit = [0.0f64; DIMS];
    let mut bins_used = 0usize;
    for b in &packing.bins {
        if b.items.is_empty() && b.used.dominant() <= EPS {
            continue;
        }
        bins_used += 1;
        for d in 0..DIMS {
            if b.capacity.0[d] > 0.0 {
                mean_load[d] += b.used.0[d] / b.capacity.0[d];
            }
            overcommit[d] = overcommit[d].max(b.used.0[d] - b.capacity.0[d]);
        }
    }
    if bins_used > 0 {
        for l in &mut mean_load {
            *l /= bins_used as f64;
        }
    }
    let ideal = ideal_bins_md(items);
    VecPackingStats {
        bins_used,
        ideal_bins: ideal,
        ratio: bins_used as f64 / ideal.max(1) as f64,
        mean_load,
        overcommit,
    }
}

/// Run one instance through several algorithms and report their stats —
/// the data behind the A1 ablation table.
pub fn compare<'a>(
    packers: &'a [&'a dyn BinPacker],
    items: &[Item],
) -> Vec<(&'a str, PackingStats)> {
    packers
        .iter()
        .map(|p| {
            let packing = p.pack(items, Vec::new());
            (p.name(), stats(&packing, items))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::{BestFit, FirstFit, FirstFitDecreasing, NextFit};

    fn items(sizes: &[f64]) -> Vec<Item> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| Item::new(i as u64, s))
            .collect()
    }

    #[test]
    fn ideal_bins_ceils() {
        assert_eq!(ideal_bins(&items(&[0.5, 0.5])), 1);
        assert_eq!(ideal_bins(&items(&[0.5, 0.6])), 2);
        assert_eq!(ideal_bins(&[]), 0);
    }

    #[test]
    fn ideal_bins_tolerates_dust() {
        let ten_tenths = vec![0.1; 10];
        assert_eq!(ideal_bins(&items(&ten_tenths)), 1);
    }

    #[test]
    fn ratio_at_least_one() {
        let its = items(&[0.6, 0.6, 0.6]);
        let p = FirstFit.pack(&its, Vec::new());
        let r = performance_ratio(&p, &its);
        assert!(r >= 1.0);
        assert_eq!(p.bins_used(), 3);
        assert!((r - 1.5).abs() < 1e-9, "3 bins vs ideal 2");
    }

    #[test]
    fn stats_mean_load_and_waste() {
        let its = items(&[0.6, 0.6]);
        let p = FirstFit.pack(&its, Vec::new());
        let s = stats(&p, &its);
        assert_eq!(s.bins_used, 2);
        assert!((s.mean_load - 0.6).abs() < 1e-9);
        assert!((s.waste - 0.8).abs() < 1e-9);
    }

    #[test]
    fn compare_covers_all_packers() {
        let packers: Vec<&dyn BinPacker> =
            vec![&FirstFit, &NextFit, &BestFit, &FirstFitDecreasing];
        let its = items(&[0.4, 0.3, 0.7, 0.2, 0.6]);
        let rows = compare(&packers, &its);
        assert_eq!(rows.len(), 4);
        for (name, s) in &rows {
            assert!(s.ratio >= 1.0, "{name} ratio {}", s.ratio);
            assert!(s.bins_used >= s.ideal_bins, "{name}");
        }
        // Next-Fit can never beat First-Fit.
        let ff = rows[0].1.bins_used;
        let nf = rows[1].1.bins_used;
        assert!(nf >= ff);
    }
}
