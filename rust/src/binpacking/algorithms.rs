//! The Any-Fit family (paper Algorithm 1) + FFD and Harmonic(k).
//!
//! All algorithms consume items strictly in sequence order (online: "each
//! item in the input sequence is assigned one by one without knowledge about
//! the following items") except [`FirstFitDecreasing`], the offline
//! comparator used to estimate how far the online result is from optimal.
//!
//! The Any-Fit packers here are the **naive `O(n·m)` reference scans**;
//! the production hot paths run the placement-identical indexed engine in
//! [`index`](crate::binpacking::index) (`O(n log m)`), and
//! `rust/tests/binpacking_equivalence.rs` keeps the two in lock-step.
//! Ties (equal residuals) always break toward the lowest bin index — the
//! paper's `b1..bm` ordering — and residual comparisons use
//! `f64::total_cmp`, so a NaN slipping into a bin's bookkeeping can never
//! panic the scheduler.

use super::index::{EngineRule, PackEngine};
use super::{Bin, Item, Packing};

/// A bin-packing algorithm. `pack` starts from `initial` bins (possibly
/// partially used — live workers with PEs already placed) and never moves
/// existing load; it only adds the new `items`.
pub trait BinPacker {
    fn name(&self) -> &'static str;

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing;

    /// Online single-item insertion into caller-owned bins. Must place
    /// exactly where `pack` would have placed the item as the next element
    /// of the stream, and must work in place — no draining or re-packing
    /// of `bins`.
    fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize;
}

/// Search criterion of an Any-Fit algorithm: which open bin takes the item?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnyFit {
    /// Lowest-index bin that fits (R = 1.7).
    First,
    /// Only the most recently opened bin is considered (R = 2).
    Next,
    /// Fitting bin with the *least* residual space (R = 1.7).
    Best,
    /// Fitting bin with the *most* residual space (R = 2).
    Worst,
}

/// Linear scan for the fitting bin whose residual is strictly "better"
/// than the best seen so far — strictness makes ties keep the earliest
/// (lowest-index) bin, the canonical tie-break shared with the indexed
/// engine. `total_cmp` keeps the scan total even for NaN residuals.
fn select_extreme(
    bins: &[Bin],
    item: &Item,
    better: impl Fn(f64, f64) -> bool,
) -> Option<usize> {
    let mut chosen: Option<(usize, f64)> = None;
    for (i, b) in bins.iter().enumerate() {
        if !b.fits(item) {
            continue;
        }
        let r = b.residual();
        match chosen {
            Some((_, cur)) if !better(r, cur) => {}
            _ => chosen = Some((i, r)),
        }
    }
    chosen.map(|(i, _)| i)
}

fn any_fit_select(rule: AnyFit, bins: &[Bin], item: &Item, cursor: usize) -> Option<usize> {
    use std::cmp::Ordering;
    match rule {
        AnyFit::First => bins.iter().position(|b| b.fits(item)),
        AnyFit::Next => {
            if cursor < bins.len() && bins[cursor].fits(item) {
                Some(cursor)
            } else {
                None
            }
        }
        AnyFit::Best => select_extreme(bins, item, |cand, cur| {
            cand.total_cmp(&cur) == Ordering::Less
        }),
        AnyFit::Worst => select_extreme(bins, item, |cand, cur| {
            cand.total_cmp(&cur) == Ordering::Greater
        }),
    }
}

/// Place one item into caller-owned bins with `rule`'s scan, opening a new
/// bin only when nothing fits. In place and allocation-free (beyond bin
/// growth) — the incremental counterpart of `any_fit_pack`'s loop body,
/// used by every Any-Fit `pack_one`.
pub fn any_fit_insert(rule: AnyFit, bins: &mut Vec<Bin>, item: Item) -> usize {
    // Next-Fit's open bin is always the most recently opened one.
    let cursor = bins.len().saturating_sub(1);
    let idx = match any_fit_select(rule, bins, &item, cursor) {
        Some(i) => i,
        None => {
            bins.push(Bin::new());
            bins.len() - 1
        }
    };
    bins[idx].push(item);
    idx
}

/// The harmonic class of a size: `j` with `size ∈ (1/(j+1), 1/j]`, sizes
/// ≤ `1/k` collapsing into class `k`.
pub(crate) fn harmonic_class(size: f64, k: usize) -> usize {
    let j = crate::util::cast::f64_to_usize((1.0 / size).floor());
    j.clamp(1, k)
}

/// Incremental Harmonic(k) insertion into caller-owned bins. The open bin
/// of the item's class is recovered as the *last* bin holding only items
/// of that class; *loaded* bins without recorded items (`Bin::with_used`
/// snapshots of live workers) are treated as closed, while **empty** bins
/// are claimable when a new class bin opens — all matching the batch
/// packer. Feeding a stream through this one item at a time is
/// placement-identical to one batch `Harmonic::pack` call. For long-lived
/// `O(1)` insertion hold a
/// [`PackEngine`](crate::binpacking::index::PackEngine) instead.
pub fn harmonic_insert(k: usize, bins: &mut Vec<Bin>, item: Item) -> usize {
    assert!(k >= 2, "harmonic needs k >= 2");
    let class = harmonic_class(item.size, k);
    let open: Option<usize> = bins
        .iter()
        .enumerate()
        .rev()
        .find(|(_, b)| {
            !b.items.is_empty() && b.items.iter().all(|it| harmonic_class(it.size, k) == class)
        })
        .and_then(|(i, b)| {
            // The open bin may be full (j items) or closed by float dust —
            // then a fresh bin opens, exactly like the batch packer.
            (b.items.len() < class && b.fits(&item)).then_some(i)
        });
    let idx = match open {
        Some(i) => i,
        // Same open rule as the batch packer: claim the lowest-index
        // empty bin before pushing a fresh one.
        None => bins
            .iter()
            .position(|b| b.used <= super::EPS && b.items.is_empty())
            .unwrap_or_else(|| {
                bins.push(Bin::new());
                bins.len() - 1
            }),
    };
    bins[idx].push(item);
    idx
}

fn any_fit_pack(rule: AnyFit, items: &[Item], initial: Vec<Bin>) -> Packing {
    let mut bins = initial;
    // Next-Fit's "current" bin starts at the last existing bin.
    let mut cursor = bins.len().saturating_sub(1);
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let choice = any_fit_select(rule, &bins, item, cursor);
        let idx = match choice {
            Some(i) => i,
            None => {
                // Algorithm 1: a new bin is generated only when no active
                // bin can fit the next item.
                bins.push(Bin::new());
                cursor = bins.len() - 1;
                cursor
            }
        };
        bins[idx].push(*item);
        assignments.push(idx);
    }
    Packing { assignments, bins }
}

macro_rules! any_fit_packer {
    ($(#[$doc:meta])* $name:ident, $rule:expr, $label:literal) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, Default)]
        pub struct $name;

        impl BinPacker for $name {
            fn name(&self) -> &'static str {
                $label
            }

            fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
                any_fit_pack($rule, items, initial)
            }

            fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize {
                any_fit_insert($rule, bins, item)
            }
        }
    };
}

any_fit_packer!(
    /// First-Fit: the paper's algorithm of choice (R = 1.7, O(n log n) with
    /// a tree index — see [`FirstFitTree`](crate::binpacking::algorithms) in
    /// the bench for the indexed variant).
    FirstFit,
    AnyFit::First,
    "first-fit"
);
any_fit_packer!(
    /// Next-Fit: only the most recent bin stays open (R = 2).
    NextFit,
    AnyFit::Next,
    "next-fit"
);
any_fit_packer!(
    /// Best-Fit: tightest fitting bin (R = 1.7).
    BestFit,
    AnyFit::Best,
    "best-fit"
);
any_fit_packer!(
    /// Worst-Fit: emptiest fitting bin (R = 2).
    WorstFit,
    AnyFit::Worst,
    "worst-fit"
);

/// Offline First-Fit-Decreasing (sorts by size, descending; 11/9·OPT+6/9).
/// Not online — used purely as the quality yardstick in the ablations.
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstFitDecreasing;

impl BinPacker for FirstFitDecreasing {
    fn name(&self) -> &'static str {
        "first-fit-decreasing"
    }

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|&a, &b| items[b].size.total_cmp(&items[a].size));
        let sorted: Vec<Item> = order.iter().map(|&i| items[i]).collect();
        // The inner First-Fit runs on the indexed engine (placement-
        // identical to the naive scan), so the offline comparator stays
        // usable at 10⁵–10⁶ items.
        let packing = PackEngine::new(EngineRule::First, initial).pack_all(&sorted);
        // Un-permute assignments back to input order.
        let mut assignments = vec![0usize; items.len()];
        for (sorted_pos, &orig) in order.iter().enumerate() {
            assignments[orig] = packing.assignments[sorted_pos];
        }
        Packing {
            assignments,
            bins: packing.bins,
        }
    }

    /// A single item is its own decreasing order — plain First-Fit.
    fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize {
        any_fit_insert(AnyFit::First, bins, item)
    }
}

/// Harmonic(k) (Lee & Lee 1985): items are classified by size into harmonic
/// intervals `(1/(j+1), 1/j]`; each class packs Next-Fit into its own bins
/// (class j bins hold exactly j items). *Loaded* pre-existing bins are
/// treated as closed — Harmonic never mixes classes into a bin whose
/// contents it can't classify — but **empty** pre-existing bins (idle
/// workers) are claimed, lowest index first, when a class opens a new bin.
#[derive(Clone, Copy, Debug)]
pub struct Harmonic {
    pub k: usize,
}

impl Default for Harmonic {
    fn default() -> Self {
        Harmonic { k: 7 }
    }
}

impl BinPacker for Harmonic {
    fn name(&self) -> &'static str {
        "harmonic-k"
    }

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
        assert!(self.k >= 2, "harmonic needs k >= 2");
        let mut bins = initial;
        // Per class j (1..=k): open bin index + count of items inside.
        let mut open: Vec<Option<(usize, usize)>> = vec![None; self.k + 1];
        // Claimable empty bins can only come from `initial` (bins opened
        // mid-pack get an item immediately); once this count hits zero the
        // per-open scan is skipped, keeping the no-initial-bins case O(1)
        // amortized per item.
        let mut free_candidates = bins
            .iter()
            .filter(|b| b.used <= super::EPS && b.items.is_empty())
            .count();
        let mut assignments = Vec::with_capacity(items.len());
        for item in items {
            let class = harmonic_class(item.size, self.k);
            let capacity_items = class; // class-j bin holds j items of size <= 1/j
            let idx = match open[class] {
                Some((idx, count)) if count < capacity_items && bins[idx].fits(item) => {
                    open[class] = Some((idx, count + 1));
                    idx
                }
                _ => {
                    // A new class bin claims the lowest-index *empty* bin
                    // first (an idle worker is trivially class-pure);
                    // loaded pre-existing bins stay closed.
                    let claimed = if free_candidates > 0 {
                        bins.iter()
                            .position(|b| b.used <= super::EPS && b.items.is_empty())
                    } else {
                        None
                    };
                    let idx = match claimed {
                        Some(i) => {
                            free_candidates -= 1;
                            i
                        }
                        None => {
                            bins.push(Bin::new());
                            bins.len() - 1
                        }
                    };
                    open[class] = Some((idx, 1));
                    idx
                }
            };
            bins[idx].push(*item);
            assignments.push(idx);
        }
        Packing { assignments, bins }
    }

    /// Incremental insertion that recovers each class's open bin from the
    /// bin contents (see [`harmonic_insert`]).
    fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize {
        harmonic_insert(self.k, bins, item)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, Config};
    use crate::util::rng::Rng;

    fn items(sizes: &[f64]) -> Vec<Item> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| Item::new(i as u64, s))
            .collect()
    }

    #[test]
    fn first_fit_textbook_sequence() {
        // Classic example: FF([0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6])
        let its = items(&[0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6]);
        let p = FirstFit.pack(&its, Vec::new());
        p.check(&its).unwrap();
        // item0 (0.5) -> bin0; item1 (0.7) -> bin1; item2 (0.5) -> bin0;
        // item3 (0.2) -> bin1; item4 (0.4) -> bin2; ...
        assert_eq!(p.assignments[0], 0);
        assert_eq!(p.assignments[1], 1);
        assert_eq!(p.assignments[2], 0);
        assert_eq!(p.assignments[3], 1);
        assert_eq!(p.assignments[4], 2);
        // Final loads: b0=1.0, b1=1.0 (0.7+0.2+0.1), b2=0.6, b3=0.5, b4=0.6.
        assert_eq!(p.bins_used(), 5);
    }

    #[test]
    fn first_fit_prefers_lowest_index() {
        let its = items(&[0.6, 0.6, 0.3]);
        let p = FirstFit.pack(&its, Vec::new());
        // 0.3 fits into bin0 (0.6 used) — lowest index, even though bin1
        // has identical residual.
        assert_eq!(p.assignments[2], 0);
    }

    #[test]
    fn next_fit_never_looks_back() {
        let its = items(&[0.6, 0.6, 0.3]);
        let p = NextFit.pack(&its, Vec::new());
        // 0.3 goes into the current (last) bin, not bin0.
        assert_eq!(p.assignments[2], 1);
    }

    #[test]
    fn best_fit_picks_tightest() {
        // bins: [0.7 used], [0.5 used]; item 0.3 fits both; Best-Fit picks
        // the one leaving least residual -> the 0.7 bin.
        let initial = vec![Bin::with_used(0.7), Bin::with_used(0.5)];
        let mut bins = initial.clone();
        let idx = BestFit.pack_one(Item::new(9, 0.3), &mut bins);
        assert_eq!(idx, 0);
        // Worst-Fit picks the emptiest.
        let mut bins = initial;
        let idx = WorstFit.pack_one(Item::new(9, 0.3), &mut bins);
        assert_eq!(idx, 1);
    }

    #[test]
    fn respects_preexisting_load() {
        let initial = vec![Bin::with_used(0.95)];
        let its = items(&[0.2]);
        let p = FirstFit.pack(&its, initial);
        assert_eq!(p.assignments[0], 1, "must open a new bin");
    }

    #[test]
    fn ffd_beats_or_ties_ff_on_adversarial_input() {
        // Ascending sizes are First-Fit's bad case.
        let sizes: Vec<f64> = (1..=40).map(|i| 0.1 + 0.02 * (i % 10) as f64).collect();
        let its = items(&sizes);
        let ff = FirstFit.pack(&its, Vec::new()).bins_used();
        let ffd = FirstFitDecreasing.pack(&its, Vec::new()).bins_used();
        assert!(ffd <= ff, "ffd={ffd} ff={ff}");
    }

    #[test]
    fn ffd_assignments_follow_input_order() {
        let its = items(&[0.2, 0.9]);
        let p = FirstFitDecreasing.pack(&its, Vec::new());
        p.check(&its).unwrap();
        // 0.9 is packed first (bin 0), then 0.2 (doesn't fit -> bin 1).
        assert_eq!(p.assignments[1], 0);
        assert_eq!(p.assignments[0], 1);
    }

    #[test]
    fn harmonic_segregates_classes() {
        let its = items(&[0.6, 0.35, 0.34, 0.2, 0.19, 0.18]);
        let p = Harmonic { k: 4 }.pack(&its, Vec::new());
        p.check(&its).unwrap();
        // Class 1 (0.6), class 2 (0.35, 0.34 -> one bin of 2), class 4/5
        // items share no bin with other classes.
        assert_eq!(p.assignments[1], p.assignments[2]);
        assert_ne!(p.assignments[0], p.assignments[1]);
    }

    #[test]
    fn harmonic_ignores_loaded_preexisting_bins() {
        let p = Harmonic::default().pack(&items(&[0.5]), vec![Bin::with_used(0.1)]);
        assert_eq!(p.assignments[0], 1);
    }

    #[test]
    fn harmonic_claims_empty_preexisting_bins() {
        // Idle workers (empty bins) are usable; the loaded bin stays
        // closed. Both class-2 items share the claimed bin.
        let initial = vec![Bin::with_used(0.0), Bin::with_used(0.6)];
        let p = Harmonic::default().pack(&items(&[0.5, 0.4]), initial);
        p.check(&items(&[0.5, 0.4])).unwrap();
        assert_eq!(p.assignments, vec![0, 0]);
    }

    // ---- property tests over the whole family ----

    fn packers() -> Vec<Box<dyn BinPacker>> {
        vec![
            Box::new(FirstFit),
            Box::new(NextFit),
            Box::new(BestFit),
            Box::new(WorstFit),
            Box::new(FirstFitDecreasing),
            Box::new(Harmonic::default()),
        ]
    }

    #[test]
    fn prop_no_overflow_and_all_assigned() {
        testkit::forall(
            Config::default(),
            |rng| testkit::gen_item_sizes(rng, 60),
            testkit::shrink_f64_vec,
            |sizes| {
                let its = items(sizes);
                for p in packers() {
                    let packing = p.pack(&its, Vec::new());
                    packing
                        .check(&its)
                        .map_err(|e| format!("{}: {e}", p.name()))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_anyfit_never_opens_bin_when_one_fits() {
        // Any-Fit group invariant (paper §IV-A): a new bin is opened only
        // if the item fits in no active bin.
        testkit::forall(
            Config::default(),
            |rng| testkit::gen_item_sizes(rng, 40),
            testkit::shrink_f64_vec,
            |sizes| {
                let its = items(sizes);
                for rule in [AnyFit::First, AnyFit::Best, AnyFit::Worst] {
                    let mut bins: Vec<Bin> = Vec::new();
                    for item in &its {
                        let before = bins.clone();
                        let packing = any_fit_pack(rule, std::slice::from_ref(item), bins);
                        bins = packing.bins;
                        let idx = packing.assignments[0];
                        if idx == before.len() {
                            // Opened a new bin: verify nothing fitted.
                            if before.iter().any(|b| b.fits(item)) {
                                return Err(format!(
                                    "{rule:?} opened a bin although item {} fits",
                                    item.size
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_first_fit_ratio_bound() {
        // FF uses at most 1.7·OPT + 2 bins; with OPT >= ceil(sum) this gives
        // a checkable (loose) bound.
        testkit::forall_no_shrink(
            Config {
                cases: 100,
                ..Config::default()
            },
            |rng| {
                let n = rng.range(1, 200) as usize;
                (0..n).map(|_| rng.uniform(0.01, 1.0)).collect::<Vec<f64>>()
            },
            |sizes| {
                let its = items(sizes);
                let used = FirstFit.pack(&its, Vec::new()).bins_used();
                let ideal = sizes.iter().sum::<f64>().ceil() as usize;
                if used as f64 <= 1.7 * ideal as f64 + 2.0 {
                    Ok(())
                } else {
                    Err(format!("FF used {used} bins, ideal {ideal}"))
                }
            },
        );
    }

    #[test]
    fn prop_pack_one_equals_pack_sequence() {
        // Feeding items one at a time must give the same result as one
        // batch call — the IRM relies on this (it packs per control cycle).
        testkit::forall(
            Config {
                cases: 100,
                ..Config::default()
            },
            |rng| testkit::gen_item_sizes(rng, 30),
            testkit::shrink_f64_vec,
            |sizes| {
                let its = items(sizes);
                let batch = FirstFit.pack(&its, Vec::new());
                let mut bins: Vec<Bin> = Vec::new();
                let mut one_by_one = Vec::new();
                for item in &its {
                    one_by_one.push(FirstFit.pack_one(*item, &mut bins));
                }
                if batch.assignments == one_by_one {
                    Ok(())
                } else {
                    Err(format!(
                        "batch {:?} != incremental {:?}",
                        batch.assignments, one_by_one
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_harmonic_class_capacity() {
        // A class-j Harmonic bin never holds more than j items.
        let mut rng = Rng::seeded(77);
        for _ in 0..50 {
            let sizes: Vec<f64> = (0..rng.range(1, 80))
                .map(|_| rng.uniform(0.01, 1.0))
                .collect();
            let its = items(&sizes);
            let k = 5;
            let p = Harmonic { k }.pack(&its, Vec::new());
            for b in &p.bins {
                if b.items.is_empty() {
                    continue;
                }
                let min_size = b.items.iter().map(|i| i.size).fold(f64::MAX, f64::min);
                let mut j = (1.0 / min_size).floor() as usize;
                j = j.clamp(1, k);
                assert!(
                    b.items.len() <= j,
                    "class-{j} bin holds {} items",
                    b.items.len()
                );
            }
        }
    }
}
