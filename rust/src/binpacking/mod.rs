//! Online bin-packing (Section IV of the paper).
//!
//! Items are PE container-hosting requests with sizes in `(0, 1]` (the
//! workload's profiled CPU fraction); bins are worker VMs with capacity 1.0.
//! The paper builds its IRM on **First-Fit** (R = 1.7, `O(n log n)` time):
//! *"The search criterion in First-Fit is to find the first (lowest index)
//! available bin in the list in which the current item fits."*
//!
//! This module provides the whole Any-Fit family from the paper's Algorithm 1
//! (First-, Next-, Best-, Worst-Fit), the offline First-Fit-Decreasing
//! lower-bound comparator, and the classic Harmonic(k) algorithm, plus
//! packing-quality analysis (`ceil(Σ sizes)` ideal, asymptotic-ratio
//! estimates) used by the ablation bench (DESIGN.md A1).
//!
//! ## Architecture: naive oracles + the indexed engine
//!
//! Every algorithm exists twice, deliberately:
//!
//! * [`algorithms`] holds the **naive reference scans** — direct
//!   transcriptions of Algorithm 1, `O(m)` per item. They are the
//!   property-test oracles and stay the ground truth for placement
//!   semantics (ties on equal residuals break toward the lowest bin
//!   index; residual comparisons use `f64::total_cmp` so NaN can never
//!   panic the scheduler).
//! * [`index`] holds the **indexed engine** ([`PackEngine`] /
//!   [`IndexedPacker`]): the same placement decisions from purpose-built
//!   indexes, used by the IRM allocator and the simulator hot loops.
//!   `rust/tests/binpacking_equivalence.rs` proves naive ≡ indexed over
//!   random streams, including pre-populated bins.
//!
//! Per-item placement complexity (m = open bins):
//!
//! | algorithm | naive scan | indexed | index structure |
//! |---|---|---|---|
//! | First-Fit | `O(m)` | `O(log m)` | max-residual segment tree, leftmost-fit descent |
//! | Next-Fit | `O(1)` | `O(1)` | open-bin cursor |
//! | Best-Fit | `O(m)` | `O(log m)` | ordered residual map (successor query) |
//! | Worst-Fit | `O(m)` | `O(log m)` | max-residual segment tree, leftmost-max descent |
//! | Harmonic(k) | `O(1)` amortized | `O(1)` (`O(log m)` when opening) | per-class open-bin buckets + free-bin pool |
//! | FFD (offline) | `O(n log n + n·m)` | `O(n log n + n log m)` | sorted prefix + First-Fit tree |
//!
//! Incremental use (the IRM's per-control-cycle pattern) goes through
//! [`PackEngine::sync_used`], which reconciles the engine to the live
//! worker loads in place — no per-tick `Vec<Bin>` rebuild, no re-pack.
//!
//! ## Multi-dimensional (vector) packing
//!
//! The paper's stated future work — packing over CPU, RAM and network at
//! once — lives in [`multidim`] ([`ResourceVec`] items, heterogeneous
//! [`VecBin`] flavor capacities, and naive oracles for the whole vector
//! Any-Fit family plus Harmonic — [`multidim::VecRule`]) and
//! [`index::VecPackEngine`] (the indexed engine the IRM runs when
//! `IrmConfig::resource_model` selects
//! [`ResourceModel::Vector`](crate::irm::config::ResourceModel); every
//! scalar `PackerChoice` maps onto its vector twin).
//! `rust/tests/binpacking_multidim_equivalence.rs` keeps oracles and
//! engine in lock-step over random flavor mixes.

pub mod algorithms;
pub mod analysis;
pub mod first_fit_tree;
pub mod index;
pub mod multidim;

pub use algorithms::{
    any_fit_insert, harmonic_insert, AnyFit, BestFit, BinPacker, FirstFit, FirstFitDecreasing,
    Harmonic, NextFit, WorstFit,
};
pub use first_fit_tree::FirstFitTree;
pub use index::{
    first_fit_md_indexed, pack_md_indexed, EngineRule, IndexedPacker, PackEngine, VecPackEngine,
};
pub use multidim::{
    best_fit_md_in, first_fit_md, first_fit_md_in, harmonic_md_in, ideal_bins_md,
    ideal_bins_md_in, next_fit_md_in, pack_md_in, worst_fit_md_in, Resource, ResourceVec, VecBin,
    VecItem, VecPacking, VecRule, DIMS,
};
pub use analysis::{ideal_bins, performance_ratio, stats_md, PackingStats, VecPackingStats};

/// An item to pack: `size` must lie in `(0, 1]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Item {
    /// Caller-side identifier (e.g. the container request id).
    pub id: u64,
    /// CPU fraction in `(0, 1]`.
    pub size: f64,
}

impl Item {
    pub fn new(id: u64, size: f64) -> Self {
        assert!(
            size > 0.0 && size <= 1.0,
            "item size must be in (0,1], got {size}"
        );
        Item { id, size }
    }
}

/// A bin (worker VM) with unit capacity by default. Bins may start
/// partially full (`used > 0`): the IRM packs *new* requests around the PEs
/// already placed on live workers.
#[derive(Clone, Debug, Default)]
pub struct Bin {
    pub used: f64,
    pub items: Vec<Item>,
}

/// Numerical slack when testing "fits": measured CPU fractions are floats
/// and a worker loaded to 0.999999 must still count as full.
pub const EPS: f64 = 1e-9;

/// Looser tolerance used by invariant *checks* (`Packing::check`,
/// `VecPacking::check`, the ablation overcommit assertions): accumulated
/// float dust across a whole packing can exceed [`EPS`], but anything past
/// this slack is a real accounting bug.
pub const CHECK_SLACK: f64 = 1e-6;

impl Bin {
    pub fn new() -> Self {
        Bin::default()
    }

    pub fn with_used(used: f64) -> Self {
        assert!((0.0..=1.0 + EPS).contains(&used));
        Bin {
            used,
            items: Vec::new(),
        }
    }

    pub fn residual(&self) -> f64 {
        (1.0 - self.used).max(0.0)
    }

    pub fn fits(&self, item: &Item) -> bool {
        item.size <= self.residual() + EPS
    }

    pub fn push(&mut self, item: Item) {
        debug_assert!(self.fits(&item), "push would overflow bin");
        self.used += item.size;
        self.items.push(item);
    }
}

/// Result of a packing run: `assignments[i]` is the bin index of `items[i]`.
#[derive(Clone, Debug, Default)]
pub struct Packing {
    pub assignments: Vec<usize>,
    pub bins: Vec<Bin>,
}

impl Packing {
    /// Number of non-empty bins.
    pub fn bins_used(&self) -> usize {
        self.bins.iter().filter(|b| b.used > EPS).count()
    }

    /// Invariant check: no bin exceeds capacity; every item assigned once.
    pub fn check(&self, items: &[Item]) -> Result<(), String> {
        for (i, b) in self.bins.iter().enumerate() {
            let sum: f64 = b.items.iter().map(|it| it.size).sum();
            if b.used > 1.0 + CHECK_SLACK {
                return Err(format!("bin {i} overflows: used={}", b.used));
            }
            // `used` may include pre-existing load not in `items`.
            if sum > b.used + CHECK_SLACK {
                return Err(format!(
                    "bin {i} accounting broken: items sum {sum} > used {}",
                    b.used
                ));
            }
        }
        if self.assignments.len() != items.len() {
            return Err(format!(
                "expected {} assignments, got {}",
                items.len(),
                self.assignments.len()
            ));
        }
        for (i, &b) in self.assignments.iter().enumerate() {
            if b >= self.bins.len() {
                return Err(format!("item {i} assigned to missing bin {b}"));
            }
            if !self.bins[b].items.iter().any(|it| it.id == items[i].id) {
                return Err(format!("item {i} not present in its bin {b}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_validates_size() {
        let _ = Item::new(0, 0.5);
        let _ = Item::new(1, 1.0);
    }

    #[test]
    #[should_panic(expected = "(0,1]")]
    fn item_rejects_zero() {
        let _ = Item::new(0, 0.0);
    }

    #[test]
    #[should_panic(expected = "(0,1]")]
    fn item_rejects_oversize() {
        let _ = Item::new(0, 1.2);
    }

    #[test]
    fn bin_residual_and_fits() {
        let mut b = Bin::new();
        assert!(b.fits(&Item::new(0, 1.0)));
        b.push(Item::new(0, 0.6));
        assert!((b.residual() - 0.4).abs() < 1e-12);
        assert!(b.fits(&Item::new(1, 0.4)));
        assert!(!b.fits(&Item::new(2, 0.41)));
    }

    #[test]
    fn bin_with_preexisting_load() {
        let b = Bin::with_used(0.75);
        assert!(b.fits(&Item::new(0, 0.25)));
        assert!(!b.fits(&Item::new(1, 0.3)));
    }

    #[test]
    fn fits_tolerates_float_dust() {
        let mut b = Bin::new();
        for i in 0..10 {
            b.push(Item::new(i, 0.1));
        }
        // used == 1.0 up to float dust; a fresh 0.1 item must not fit but
        // residual must not be negative either.
        assert!(b.residual() >= 0.0);
        assert!(!b.fits(&Item::new(99, 0.1)));
    }
}
