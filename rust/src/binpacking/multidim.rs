//! Multi-dimensional (vector) online bin-packing — the paper's stated
//! future work: *"we would like [to] further extend our approach with
//! multi-dimensional online bin-packing [...] to profile and schedule
//! workloads based on more resources than only CPU, such as RAM, network
//! usage, or even variations of CPU metrics like average, maximum etc."*
//!
//! Items carry a resource vector; bins carry a **capacity** vector (VM
//! flavors — an SSC.large worker has half the cores and half the RAM of
//! the SSC.xlarge reference, but the same NIC). An item fits when every
//! component fits. First-Fit generalizes directly; the quality lower
//! bound becomes `max_d ceil(Σ_i size_i[d] / cap[d])`.
//!
//! All sizes and capacities are expressed in **reference-VM units**: `1.0`
//! in a dimension is the whole reference flavor (the paper's SSC.xlarge).
//! Heterogeneous clouds show up as bins whose capacity is below (or at)
//! the unit vector.
//!
//! [`first_fit_md_in`] is the naive `O(n·m)` **oracle** for placement
//! semantics; the production hot path is the placement-identical
//! [`VecPackEngine`](crate::binpacking::index::VecPackEngine)
//! (`O(log m)` expected per item, property-tested in
//! `rust/tests/binpacking_multidim_equivalence.rs`).
//!
//! ## The vector Any-Fit family
//!
//! Every scalar rule has a vector twin ([`VecRule`], selected in the IRM
//! through `PackerChoice` exactly like the scalar rules):
//!
//! * **First-Fit** — lowest-index bin where every component fits.
//! * **Next-Fit** — only the most recently opened bin is considered.
//! * **Best-/Worst-Fit** — among the fitting bins, pick the extreme of the
//!   **residual norm** `Σ_d residual_d` (the L1 norm of the residual
//!   vector): Best minimizes it (tightest bin overall), Worst maximizes it
//!   (emptiest). Ties break toward the lowest bin index, comparisons use
//!   `total_cmp`. On CPU-only items over equal-capacity bins the non-CPU
//!   residual terms are constant across bins, so the selection reduces
//!   exactly to the scalar Best-/Worst-Fit residual ordering.
//! * **Harmonic(k)** — class buckets keyed on the item's **dominant
//!   dimension**: class = `(dominant_dim, j)` with the dominant component
//!   in `(1/(j+1), 1/j]`. A class-`(d,j)` bin accepts at most `j` items,
//!   all of that class; empty pre-loaded bins (idle workers) are claimable
//!   by the lowest index *where the item fits*, loaded pre-loaded bins
//!   stay closed — the flavor-aware generalization of the scalar rule.
//!
//! The naive scans here ([`pack_md_in`] dispatches over them) remain the
//! property-test oracles for the indexed
//! [`VecPackEngine`](crate::binpacking::index::VecPackEngine) twins.

use std::collections::HashMap;
use std::fmt;

use super::algorithms::harmonic_class;
use super::{CHECK_SLACK, EPS};

/// Resource dimensions used by the extended profiler.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Resource {
    Cpu = 0,
    Ram = 1,
    Net = 2,
}

pub const DIMS: usize = 3;

/// A point in resource space, in reference-VM units (`1.0` = the whole
/// reference flavor in that dimension).
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct ResourceVec(pub [f64; DIMS]);

impl ResourceVec {
    /// No demand in any dimension.
    pub const ZERO: ResourceVec = ResourceVec([0.0; DIMS]);
    /// The reference flavor's capacity (the paper's unit bin).
    pub const UNIT: ResourceVec = ResourceVec([1.0; DIMS]);

    pub fn new(cpu: f64, ram: f64, net: f64) -> Self {
        ResourceVec([cpu, ram, net])
    }

    pub fn cpu(cpu: f64) -> Self {
        ResourceVec([cpu, 0.0, 0.0])
    }

    pub fn get(&self, r: Resource) -> f64 {
        self.0[r as usize]
    }

    pub fn set(&mut self, r: Resource, v: f64) {
        self.0[r as usize] = v;
    }

    pub fn add(&self, rhs: &ResourceVec) -> ResourceVec {
        let mut out = [0.0; DIMS];
        for d in 0..DIMS {
            out[d] = self.0[d] + rhs.0[d];
        }
        ResourceVec(out)
    }

    /// Component-wise minimum with `cap` — clamp a demand to a capacity.
    pub fn clamp_to(&self, cap: &ResourceVec) -> ResourceVec {
        let mut out = [0.0; DIMS];
        for d in 0..DIMS {
            out[d] = self.0[d].min(cap.0[d]);
        }
        ResourceVec(out)
    }

    /// Component-wise `used + self <= 1 + eps` (unit-capacity fit).
    pub fn fits_into(&self, used: &ResourceVec, eps: f64) -> bool {
        self.fits_within(used, &ResourceVec::UNIT, eps)
    }

    /// Component-wise `used + self <= cap + eps`.
    pub fn fits_within(&self, used: &ResourceVec, cap: &ResourceVec, eps: f64) -> bool {
        (0..DIMS).all(|d| used.0[d] + self.0[d] <= cap.0[d] + eps)
    }

    /// The dominant (largest) component — used for size-ordering
    /// heuristics.
    pub fn dominant(&self) -> f64 {
        self.0.iter().cloned().fold(0.0, f64::max)
    }

    /// Index of the dominant component (lowest index on ties) — the
    /// dimension the indexed engine keys its candidate search on.
    pub fn dominant_dim(&self) -> usize {
        let mut best = 0;
        for d in 1..DIMS {
            if self.0[d] > self.0[best] {
                best = d;
            }
        }
        best
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "(cpu {:.2}, ram {:.2}, net {:.2})",
            self.0[0], self.0[1], self.0[2]
        )
    }
}

/// A multi-dimensional item.
#[derive(Clone, Copy, Debug)]
pub struct VecItem {
    pub id: u64,
    pub size: ResourceVec,
}

impl VecItem {
    pub fn new(id: u64, size: ResourceVec) -> Self {
        for (d, v) in size.0.iter().enumerate() {
            assert!(
                (0.0..=1.0).contains(v),
                "dimension {d} out of [0,1]: {v}"
            );
        }
        assert!(size.dominant() > 0.0, "item must demand something");
        VecItem { id, size }
    }
}

/// A multi-dimensional bin with a per-flavor capacity vector.
#[derive(Clone, Debug)]
pub struct VecBin {
    /// Flavor capacity in reference-VM units (`UNIT` = the reference VM).
    pub capacity: ResourceVec,
    pub used: ResourceVec,
    pub items: Vec<VecItem>,
}

impl Default for VecBin {
    fn default() -> Self {
        VecBin::new(ResourceVec::UNIT)
    }
}

impl VecBin {
    /// An empty bin of the given flavor capacity.
    pub fn new(capacity: ResourceVec) -> Self {
        VecBin {
            capacity,
            used: ResourceVec::ZERO,
            items: Vec::new(),
        }
    }

    /// A pre-loaded bin (live worker): `used` is clamped into capacity.
    pub fn with_load(capacity: ResourceVec, used: ResourceVec) -> Self {
        VecBin {
            capacity,
            used: used.clamp_to(&capacity),
            items: Vec::new(),
        }
    }

    /// Residual capacity in dimension `d` (never negative).
    pub fn residual(&self, d: usize) -> f64 {
        (self.capacity.0[d] - self.used.0[d]).max(0.0)
    }

    /// L1 norm of the residual vector (`Σ_d residual_d`) — the selection
    /// key of vector Best-/Worst-Fit. On CPU-only items over
    /// equal-capacity bins the non-CPU terms are constant, so ordering by
    /// this norm reduces to the scalar residual ordering.
    pub fn residual_norm(&self) -> f64 {
        (0..DIMS).map(|d| self.residual(d)).sum()
    }

    pub fn fits(&self, item: &VecItem) -> bool {
        item.size.fits_within(&self.used, &self.capacity, EPS)
    }

    pub fn push(&mut self, item: VecItem) {
        debug_assert!(self.fits(&item));
        self.used = self.used.add(&item.size);
        self.items.push(item);
    }
}

/// Result of a vector packing run.
#[derive(Clone, Debug, Default)]
pub struct VecPacking {
    pub assignments: Vec<usize>,
    pub bins: Vec<VecBin>,
}

impl VecPacking {
    pub fn bins_used(&self) -> usize {
        self.bins.iter().filter(|b| !b.items.is_empty()).count()
    }

    pub fn check(&self, items: &[VecItem]) -> Result<(), String> {
        for (i, b) in self.bins.iter().enumerate() {
            for d in 0..DIMS {
                if b.used.0[d] > b.capacity.0[d] + CHECK_SLACK {
                    return Err(format!(
                        "bin {i} dim {d} overflows: {} > cap {}",
                        b.used.0[d], b.capacity.0[d]
                    ));
                }
            }
        }
        if self.assignments.len() != items.len() {
            return Err("missing assignments".into());
        }
        Ok(())
    }
}

/// Multi-dimensional First-Fit (online; lowest-index bin where every
/// component fits) over possibly heterogeneous `initial` bins; bins opened
/// beyond them get `new_capacity` (the flavor the cloud would provision).
/// This is the naive `O(n·m)` oracle the indexed engine is property-tested
/// against.
///
/// Items are fit-tested against existing bins at their **true** size (a
/// demand bigger than the provisioning flavor may still fit a larger
/// live flavor); only when nothing fits and a `new_capacity` bin must
/// open is the item clamped into that flavor — a demand larger than a
/// whole new VM gets the whole VM instead of wedging the stream.
pub fn first_fit_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    let mut bins = initial;
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let (idx, item) = match bins.iter().position(|b| b.fits(item)) {
            Some(i) => (i, *item),
            None => {
                bins.push(VecBin::new(new_capacity));
                (bins.len() - 1, clamp_to_flavor(*item, &new_capacity))
            }
        };
        bins[idx].push(item);
        assignments.push(idx);
    }
    VecPacking { assignments, bins }
}

/// An item as a freshly opened `capacity` bin will host it: clamped
/// component-wise into the flavor (shared by the oracle and the indexed
/// engine so their placements and bin loads stay identical). Constructed
/// directly rather than through [`VecItem::new`]: demand lying entirely
/// in dimensions the flavor cannot provision clamps to a zero-footprint
/// placement (the VM hosts the item; the model cannot account the
/// unprovisionable demand) — not a panic.
pub(crate) fn clamp_to_flavor(item: VecItem, capacity: &ResourceVec) -> VecItem {
    VecItem {
        id: item.id,
        size: item.size.clamp_to(capacity),
    }
}

/// Unit-capacity First-Fit (the paper's homogeneous setting).
pub fn first_fit_md(items: &[VecItem], initial: Vec<VecBin>) -> VecPacking {
    first_fit_md_in(items, initial, ResourceVec::UNIT)
}

/// Which vector packing rule runs (the vector twins of the scalar
/// `PackerChoice` family — see the module-level notes for each rule's
/// selection criterion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecRule {
    First,
    Next,
    Best,
    Worst,
    /// Harmonic with `k` classes per dominant dimension (k ≥ 2).
    Harmonic(usize),
}

/// Dispatch over the naive vector oracles — one `O(n·m)` reference scan
/// per rule, mirroring [`first_fit_md_in`]'s signature and open/clamp
/// semantics.
pub fn pack_md_in(
    rule: VecRule,
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    match rule {
        VecRule::First => first_fit_md_in(items, initial, new_capacity),
        VecRule::Next => next_fit_md_in(items, initial, new_capacity),
        VecRule::Best => best_fit_md_in(items, initial, new_capacity),
        VecRule::Worst => worst_fit_md_in(items, initial, new_capacity),
        VecRule::Harmonic(k) => harmonic_md_in(items, initial, new_capacity, k),
    }
}

/// Multi-dimensional Next-Fit: only the most recently opened bin is
/// considered (the last `initial` bin at batch start); everything else
/// follows [`first_fit_md_in`]'s open/clamp semantics. Naive oracle.
pub fn next_fit_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    let mut bins = initial;
    let mut cursor = bins.len().saturating_sub(1);
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let fits_cursor = cursor < bins.len() && bins[cursor].fits(item);
        let (idx, item) = if fits_cursor {
            (cursor, *item)
        } else {
            bins.push(VecBin::new(new_capacity));
            cursor = bins.len() - 1;
            (cursor, clamp_to_flavor(*item, &new_capacity))
        };
        bins[idx].push(item);
        assignments.push(idx);
    }
    VecPacking { assignments, bins }
}

/// Shared Best-/Worst-Fit scan: pick the fitting bin whose residual norm
/// is strictly "better" than the best seen so far (strictness keeps the
/// lowest index on ties; `total_cmp` keeps the scan total on NaN).
fn extreme_fit_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
    better: impl Fn(f64, f64) -> bool,
) -> VecPacking {
    let mut bins = initial;
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let mut chosen: Option<(usize, f64)> = None;
        for (i, b) in bins.iter().enumerate() {
            if !b.fits(item) {
                continue;
            }
            let norm = b.residual_norm();
            match chosen {
                Some((_, cur)) if !better(norm, cur) => {}
                _ => chosen = Some((i, norm)),
            }
        }
        let (idx, item) = match chosen {
            Some((i, _)) => (i, *item),
            None => {
                bins.push(VecBin::new(new_capacity));
                (bins.len() - 1, clamp_to_flavor(*item, &new_capacity))
            }
        };
        bins[idx].push(item);
        assignments.push(idx);
    }
    VecPacking { assignments, bins }
}

/// Multi-dimensional Best-Fit: the fitting bin minimizing the residual
/// norm (tightest overall). Naive oracle.
pub fn best_fit_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    use std::cmp::Ordering;
    extreme_fit_md_in(items, initial, new_capacity, |cand, cur| {
        cand.total_cmp(&cur) == Ordering::Less
    })
}

/// Multi-dimensional Worst-Fit: the fitting bin maximizing the residual
/// norm (emptiest overall). Naive oracle.
pub fn worst_fit_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
) -> VecPacking {
    use std::cmp::Ordering;
    extreme_fit_md_in(items, initial, new_capacity, |cand, cur| {
        cand.total_cmp(&cur) == Ordering::Greater
    })
}

/// The harmonic class bucket of an item: keyed on the dominant dimension
/// and the harmonic class of its value. Computed on the item's **true**
/// size (an item clamped into a freshly opened flavor keeps its original
/// class — both the oracle and the engine classify before clamping).
pub(crate) fn harmonic_md_class(size: &ResourceVec, k: usize) -> (usize, usize) {
    let d = size.dominant_dim();
    (d, harmonic_class(size.0[d], k))
}

/// Multi-dimensional Harmonic(k): per dominant-dimension class bucket
/// `(d, j)`, items pack Next-Fit into class-pure bins of at most `j`
/// items. Loaded pre-loaded bins are closed (their contents cannot be
/// classified); **empty** pre-loaded bins are claimed — lowest index
/// where the item fits — when a class opens a bin; otherwise a
/// `new_capacity` bin opens with [`first_fit_md_in`]'s clamp semantics.
/// Naive oracle.
pub fn harmonic_md_in(
    items: &[VecItem],
    initial: Vec<VecBin>,
    new_capacity: ResourceVec,
    k: usize,
) -> VecPacking {
    assert!(k >= 2, "harmonic needs k >= 2");
    let mut bins = initial;
    // Per class bucket: open bin index + item count inside.
    let mut open: HashMap<(usize, usize), (usize, usize)> = HashMap::new();
    // Claimable empty bins only ever come from `initial` (bins opened
    // mid-pack take an item immediately); track the count so the per-open
    // scan is skipped once they are gone.
    let mut free_candidates = bins
        .iter()
        .filter(|b| b.used.dominant() <= super::EPS && b.items.is_empty())
        .count();
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let class = harmonic_md_class(&item.size, k);
        let capacity_items = class.1;
        let reuse = match open.get(&class) {
            Some(&(idx, count)) if count < capacity_items && bins[idx].fits(item) => {
                Some((idx, count))
            }
            _ => None,
        };
        let (idx, item) = match reuse {
            Some((idx, count)) => {
                open.insert(class, (idx, count + 1));
                (idx, *item)
            }
            None => {
                // A new class bin claims the lowest-index empty bin the
                // item fits (an idle worker is trivially class-pure; a
                // too-small flavor stays free for smaller classes).
                let claimed = if free_candidates > 0 {
                    bins.iter().position(|b| {
                        b.used.dominant() <= super::EPS && b.items.is_empty() && b.fits(item)
                    })
                } else {
                    None
                };
                match claimed {
                    Some(i) => {
                        free_candidates -= 1;
                        open.insert(class, (i, 1));
                        (i, *item)
                    }
                    None => {
                        bins.push(VecBin::new(new_capacity));
                        let i = bins.len() - 1;
                        open.insert(class, (i, 1));
                        (i, clamp_to_flavor(*item, &new_capacity))
                    }
                }
            }
        };
        bins[idx].push(item);
        assignments.push(idx);
    }
    VecPacking { assignments, bins }
}

/// Lower bound on the optimal bin count at unit capacity: the tightest
/// single dimension.
pub fn ideal_bins_md(items: &[VecItem]) -> usize {
    ideal_bins_md_in(items, &ResourceVec::UNIT)
}

/// Lower bound on the optimal count of `cap`-flavor bins: per dimension,
/// `ceil(Σ demand / cap)`, maximized over dimensions. A dimension the
/// flavor cannot provision at all (zero capacity) is skipped when nothing
/// demands it; with positive demand no finite count of such bins exists,
/// which surfaces as `usize::MAX` rather than a silently understated
/// bound.
pub fn ideal_bins_md_in(items: &[VecItem], cap: &ResourceVec) -> usize {
    let mut per_dim = [0.0f64; DIMS];
    for it in items {
        for d in 0..DIMS {
            per_dim[d] += it.size.0[d];
        }
    }
    (0..DIMS)
        .map(|d| {
            if cap.0[d] <= 0.0 {
                return if per_dim[d] > EPS { usize::MAX } else { 0 };
            }
            crate::util::cast::f64_to_usize(((per_dim[d] / cap.0[d]) - EPS).ceil().max(0.0))
        })
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, Config};

    fn item(id: u64, cpu: f64, ram: f64, net: f64) -> VecItem {
        VecItem::new(id, ResourceVec::new(cpu, ram, net))
    }

    #[test]
    fn ram_constraint_forces_new_bin() {
        // CPU fits easily but RAM is the binding dimension.
        let items = vec![
            item(0, 0.1, 0.8, 0.0),
            item(1, 0.1, 0.8, 0.0),
            item(2, 0.1, 0.1, 0.0),
        ];
        let p = first_fit_md(&items, Vec::new());
        p.check(&items).unwrap();
        assert_eq!(p.assignments[0], 0);
        assert_eq!(p.assignments[1], 1, "RAM-bound spill");
        assert_eq!(p.assignments[2], 0, "small item backfills bin 0");
    }

    #[test]
    fn reduces_to_scalar_first_fit_on_cpu_only() {
        let sizes = [0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6];
        let md: Vec<VecItem> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| VecItem::new(i as u64, ResourceVec::cpu(s)))
            .collect();
        let scalar: Vec<crate::binpacking::Item> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| crate::binpacking::Item::new(i as u64, s))
            .collect();
        use crate::binpacking::BinPacker;
        let a = first_fit_md(&md, Vec::new());
        let b = crate::binpacking::FirstFit.pack(&scalar, Vec::new());
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn ideal_bins_takes_tightest_dimension() {
        let items = vec![item(0, 0.2, 0.9, 0.1), item(1, 0.2, 0.9, 0.1)];
        // CPU sum 0.4 → 1 bin; RAM sum 1.8 → 2 bins.
        assert_eq!(ideal_bins_md(&items), 2);
    }

    #[test]
    fn heterogeneous_capacity_is_respected() {
        // A half-size flavor (SSC.large-like) takes one 0.3-RAM item, not
        // two; the second spills to the unit bin behind it.
        let half = ResourceVec::new(0.5, 0.5, 1.0);
        let initial = vec![VecBin::new(half), VecBin::new(ResourceVec::UNIT)];
        let items = vec![item(0, 0.1, 0.3, 0.0), item(1, 0.1, 0.3, 0.0)];
        let p = first_fit_md_in(&items, initial, ResourceVec::UNIT);
        p.check(&items).unwrap();
        assert_eq!(p.assignments, vec![0, 1], "RAM cap 0.5 fits one 0.3 item");
    }

    #[test]
    fn new_bins_open_at_the_provisioning_flavor() {
        let small = ResourceVec::new(0.25, 0.25, 1.0);
        let items = vec![item(0, 0.2, 0.1, 0.0), item(1, 0.2, 0.1, 0.0)];
        let p = first_fit_md_in(&items, Vec::new(), small);
        p.check(&items).unwrap();
        // 0.2 cpu on a 0.25-cpu flavor: one item per bin.
        assert_eq!(p.assignments, vec![0, 1]);
        assert_eq!(p.bins[0].capacity, small);
        assert_eq!(p.bins[1].capacity, small);
    }

    #[test]
    fn ideal_bins_scales_with_flavor_capacity() {
        let items = vec![item(0, 0.4, 0.1, 0.0), item(1, 0.4, 0.1, 0.0)];
        assert_eq!(ideal_bins_md(&items), 1);
        // The same demand needs two half-size flavors (cpu 0.8 / cap 0.5).
        assert_eq!(
            ideal_bins_md_in(&items, &ResourceVec::new(0.5, 0.5, 1.0)),
            2
        );
    }

    #[test]
    fn ideal_bins_flags_unprovisionable_demand() {
        // Positive net demand against a flavor with zero net capacity:
        // no finite bin count exists — not a silently understated bound.
        let items = vec![item(0, 0.1, 0.1, 0.5)];
        let netless = ResourceVec::new(0.5, 0.5, 0.0);
        assert_eq!(ideal_bins_md_in(&items, &netless), usize::MAX);
        // With zero demand there, the dimension is simply skipped.
        let cpu_ram = vec![item(1, 0.6, 0.1, 0.0)];
        assert_eq!(ideal_bins_md_in(&cpu_ram, &netless), 2);
    }

    #[test]
    fn preloaded_bin_clamps_into_capacity() {
        let b = VecBin::with_load(
            ResourceVec::new(0.5, 0.5, 1.0),
            ResourceVec::new(0.7, 0.2, 0.0),
        );
        assert!((b.used.get(Resource::Cpu) - 0.5).abs() < 1e-12);
        assert!((b.residual(Resource::Cpu as usize)).abs() < 1e-12);
        assert!((b.residual(Resource::Ram as usize) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn prop_no_dimension_overflows() {
        testkit::forall_no_shrink(
            Config::default(),
            |rng| {
                let n = rng.below(60) as usize;
                (0..n)
                    .map(|i| {
                        VecItem::new(
                            i as u64,
                            ResourceVec::new(
                                rng.uniform(0.01, 1.0),
                                rng.uniform(0.0, 1.0),
                                rng.uniform(0.0, 1.0),
                            ),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |items| {
                let p = first_fit_md(items, Vec::new());
                p.check(items).map_err(|e| e)?;
                // Quality: never worse than one bin per item, never better
                // than the per-dimension lower bound.
                let used = p.bins_used();
                let ideal = ideal_bins_md(items);
                if used < ideal {
                    return Err(format!("impossible: used {used} < ideal {ideal}"));
                }
                if used > items.len() {
                    return Err("more bins than items".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "out of [0,1]")]
    fn rejects_oversized_dimension() {
        let _ = item(0, 0.5, 1.2, 0.0);
    }

    #[test]
    fn oversized_item_gets_the_whole_new_flavor() {
        // Nothing fits 0.4 CPU on a 0.25-CPU flavor: the item takes the
        // whole new VM (clamped) instead of wedging the stream.
        let items = vec![item(0, 0.4, 0.1, 0.0)];
        let p = first_fit_md_in(&items, Vec::new(), ResourceVec::new(0.25, 0.25, 1.0));
        p.check(&items).unwrap();
        assert_eq!(p.assignments, vec![0]);
        assert!((p.bins[0].used.get(Resource::Cpu) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn oversized_item_still_fits_a_larger_live_flavor_unclamped() {
        // A demand above the provisioning flavor must be fit-tested at its
        // true size against bigger live bins — clamping before the fit
        // check would overcommit them.
        let big = vec![item(0, 0.1, 0.8, 0.0), item(1, 0.1, 0.8, 0.0)];
        let small = ResourceVec::new(0.5, 0.5, 1.0);
        let initial = vec![VecBin::new(ResourceVec::UNIT)];
        let p = first_fit_md_in(&big, initial, small);
        p.check(&big).unwrap();
        // First takes the Xlarge at full 0.8 RAM; the second does NOT
        // also squeeze in (0.8 + 0.8 > 1.0) — it opens a clamped bin.
        assert_eq!(p.assignments, vec![0, 1]);
        assert!((p.bins[0].used.get(Resource::Ram) - 0.8).abs() < 1e-12);
        assert!((p.bins[1].used.get(Resource::Ram) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unprovisionable_demand_clamps_to_zero_footprint_not_panic() {
        // Net-only demand against a netless flavor: the item parks on a
        // new bin with zero accounted footprint instead of panicking.
        let items = vec![item(0, 0.0, 0.0, 0.5)];
        let netless = ResourceVec::new(0.5, 0.5, 0.0);
        let p = first_fit_md_in(&items, Vec::new(), netless);
        p.check(&items).unwrap();
        assert_eq!(p.assignments, vec![0]);
        assert_eq!(p.bins[0].used.dominant(), 0.0);
    }

    #[test]
    fn dominant_dim_lowest_index_on_ties() {
        assert_eq!(ResourceVec::new(0.5, 0.5, 0.1).dominant_dim(), 0);
        assert_eq!(ResourceVec::new(0.1, 0.5, 0.2).dominant_dim(), 1);
        assert_eq!(ResourceVec::new(0.1, 0.2, 0.5).dominant_dim(), 2);
    }

    #[test]
    fn next_fit_md_never_looks_back() {
        let items = vec![
            item(0, 0.6, 0.1, 0.0),
            item(1, 0.6, 0.1, 0.0),
            item(2, 0.3, 0.1, 0.0),
        ];
        let p = next_fit_md_in(&items, Vec::new(), ResourceVec::UNIT);
        p.check(&items).unwrap();
        // 0.3 fits bin 0, but only the current (last) bin is open.
        assert_eq!(p.assignments, vec![0, 1, 1]);
    }

    #[test]
    fn best_fit_md_picks_tightest_worst_picks_emptiest() {
        let loaded = |cpu: f64| VecBin::with_load(ResourceVec::UNIT, ResourceVec::cpu(cpu));
        let items = vec![item(0, 0.2, 0.1, 0.0)];
        let p = best_fit_md_in(&items, vec![loaded(0.5), loaded(0.7)], ResourceVec::UNIT);
        assert_eq!(p.assignments, vec![1], "least residual norm");
        let p = worst_fit_md_in(&items, vec![loaded(0.5), loaded(0.7)], ResourceVec::UNIT);
        assert_eq!(p.assignments, vec![0], "most residual norm");
    }

    #[test]
    fn best_fit_md_ram_can_outweigh_cpu() {
        // Bin 0 is CPU-tighter but RAM-empty; bin 1 is tighter *overall*
        // (smaller residual norm) — the vector rule must see all
        // dimensions, not just CPU.
        let bins = vec![
            VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.6, 0.0, 0.0)),
            VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.5, 0.6, 0.0)),
        ];
        let items = vec![item(0, 0.2, 0.1, 0.0)];
        let p = best_fit_md_in(&items, bins, ResourceVec::UNIT);
        assert_eq!(p.assignments, vec![1]);
    }

    #[test]
    fn harmonic_md_buckets_by_dominant_dimension() {
        // Two RAM-dominant class-2 items share a bin; the CPU-dominant
        // class-2 item gets its own bucket even though it would fit.
        let items = vec![
            item(0, 0.1, 0.4, 0.0),
            item(1, 0.1, 0.4, 0.0),
            item(2, 0.4, 0.1, 0.0),
        ];
        let p = harmonic_md_in(&items, Vec::new(), ResourceVec::UNIT, 7);
        p.check(&items).unwrap();
        assert_eq!(p.assignments[0], p.assignments[1], "same (ram, 2) bucket");
        assert_ne!(p.assignments[2], p.assignments[0], "(cpu, 2) is a new bucket");
    }

    #[test]
    fn harmonic_md_claims_fitting_empty_bins_only() {
        // The empty half-flavor bin cannot fit a 0.6-RAM item; the empty
        // unit bin behind it is claimed instead. The loaded bin is closed.
        let half = ResourceVec::new(0.5, 0.5, 1.0);
        let initial = vec![
            VecBin::with_load(ResourceVec::UNIT, ResourceVec::new(0.1, 0.1, 0.0)),
            VecBin::new(half),
            VecBin::new(ResourceVec::UNIT),
        ];
        let items = vec![item(0, 0.1, 0.6, 0.0), item(1, 0.1, 0.3, 0.0)];
        let p = harmonic_md_in(&items, initial, ResourceVec::UNIT, 7);
        p.check(&items).unwrap();
        assert_eq!(p.assignments[0], 2, "skips the too-small free flavor");
        assert_eq!(p.assignments[1], 1, "class (ram,3) claims the half flavor");
    }

    #[test]
    fn vector_rules_reduce_to_scalar_on_cpu_only_items() {
        use crate::binpacking::{BestFit, BinPacker, Harmonic, NextFit, WorstFit};
        let sizes = [0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6];
        let md: Vec<VecItem> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| VecItem::new(i as u64, ResourceVec::cpu(s)))
            .collect();
        let scalar: Vec<crate::binpacking::Item> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| crate::binpacking::Item::new(i as u64, s))
            .collect();
        let cases: Vec<(VecRule, Box<dyn BinPacker>)> = vec![
            (VecRule::Next, Box::new(NextFit)),
            (VecRule::Best, Box::new(BestFit)),
            (VecRule::Worst, Box::new(WorstFit)),
            (VecRule::Harmonic(7), Box::new(Harmonic { k: 7 })),
        ];
        for (rule, packer) in &cases {
            let a = pack_md_in(*rule, &md, Vec::new(), ResourceVec::UNIT);
            let b = packer.pack(&scalar, Vec::new());
            assert_eq!(a.assignments, b.assignments, "{rule:?}");
        }
    }
}
