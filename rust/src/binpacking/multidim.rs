//! Multi-dimensional (vector) online bin-packing — the paper's stated
//! future work: *"we would like [to] further extend our approach with
//! multi-dimensional online bin-packing [...] to profile and schedule
//! workloads based on more resources than only CPU, such as RAM, network
//! usage, or even variations of CPU metrics like average, maximum etc."*
//!
//! Items and bins carry a resource vector; an item fits when every
//! component fits. First-Fit generalizes directly; the quality lower bound
//! becomes `max_d ceil(Σ_i size_i[d])`.

use std::fmt;

/// Resource dimensions used by the extended profiler.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Resource {
    Cpu = 0,
    Ram = 1,
    Net = 2,
}

pub const DIMS: usize = 3;

/// A point in resource space, each component in `[0, 1]` of a worker.
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct ResourceVec(pub [f64; DIMS]);

impl ResourceVec {
    pub fn new(cpu: f64, ram: f64, net: f64) -> Self {
        ResourceVec([cpu, ram, net])
    }

    pub fn cpu(cpu: f64) -> Self {
        ResourceVec([cpu, 0.0, 0.0])
    }

    pub fn get(&self, r: Resource) -> f64 {
        self.0[r as usize]
    }

    pub fn add(&self, rhs: &ResourceVec) -> ResourceVec {
        let mut out = [0.0; DIMS];
        for d in 0..DIMS {
            out[d] = self.0[d] + rhs.0[d];
        }
        ResourceVec(out)
    }

    /// Component-wise `self + item <= 1 + eps`.
    pub fn fits_into(&self, used: &ResourceVec, eps: f64) -> bool {
        (0..DIMS).all(|d| used.0[d] + self.0[d] <= 1.0 + eps)
    }

    /// The dominant (largest) component — used for size-ordering
    /// heuristics.
    pub fn dominant(&self) -> f64 {
        self.0.iter().cloned().fold(0.0, f64::max)
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "(cpu {:.2}, ram {:.2}, net {:.2})",
            self.0[0], self.0[1], self.0[2]
        )
    }
}

/// A multi-dimensional item.
#[derive(Clone, Copy, Debug)]
pub struct VecItem {
    pub id: u64,
    pub size: ResourceVec,
}

impl VecItem {
    pub fn new(id: u64, size: ResourceVec) -> Self {
        for (d, v) in size.0.iter().enumerate() {
            assert!(
                (0.0..=1.0).contains(v),
                "dimension {d} out of [0,1]: {v}"
            );
        }
        assert!(size.dominant() > 0.0, "item must demand something");
        VecItem { id, size }
    }
}

/// A multi-dimensional bin.
#[derive(Clone, Debug, Default)]
pub struct VecBin {
    pub used: ResourceVec,
    pub items: Vec<VecItem>,
}

impl VecBin {
    pub fn fits(&self, item: &VecItem) -> bool {
        item.size.fits_into(&self.used, 1e-9)
    }

    pub fn push(&mut self, item: VecItem) {
        debug_assert!(self.fits(&item));
        self.used = self.used.add(&item.size);
        self.items.push(item);
    }
}

/// Result of a vector packing run.
#[derive(Clone, Debug, Default)]
pub struct VecPacking {
    pub assignments: Vec<usize>,
    pub bins: Vec<VecBin>,
}

impl VecPacking {
    pub fn bins_used(&self) -> usize {
        self.bins.iter().filter(|b| !b.items.is_empty()).count()
    }

    pub fn check(&self, items: &[VecItem]) -> Result<(), String> {
        for (i, b) in self.bins.iter().enumerate() {
            for d in 0..DIMS {
                if b.used.0[d] > 1.0 + 1e-6 {
                    return Err(format!("bin {i} dim {d} overflows: {}", b.used.0[d]));
                }
            }
        }
        if self.assignments.len() != items.len() {
            return Err("missing assignments".into());
        }
        Ok(())
    }
}

/// Multi-dimensional First-Fit (online; lowest-index bin where every
/// component fits).
pub fn first_fit_md(items: &[VecItem], initial: Vec<VecBin>) -> VecPacking {
    let mut bins = initial;
    let mut assignments = Vec::with_capacity(items.len());
    for item in items {
        let idx = match bins.iter().position(|b| b.fits(item)) {
            Some(i) => i,
            None => {
                bins.push(VecBin::default());
                bins.len() - 1
            }
        };
        bins[idx].push(*item);
        assignments.push(idx);
    }
    VecPacking { assignments, bins }
}

/// Lower bound on the optimal bin count: the tightest single dimension.
pub fn ideal_bins_md(items: &[VecItem]) -> usize {
    let mut per_dim = [0.0f64; DIMS];
    for it in items {
        for d in 0..DIMS {
            per_dim[d] += it.size.0[d];
        }
    }
    per_dim
        .iter()
        .map(|s| (s - 1e-9).ceil().max(0.0) as usize)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, Config};

    fn item(id: u64, cpu: f64, ram: f64, net: f64) -> VecItem {
        VecItem::new(id, ResourceVec::new(cpu, ram, net))
    }

    #[test]
    fn ram_constraint_forces_new_bin() {
        // CPU fits easily but RAM is the binding dimension.
        let items = vec![
            item(0, 0.1, 0.8, 0.0),
            item(1, 0.1, 0.8, 0.0),
            item(2, 0.1, 0.1, 0.0),
        ];
        let p = first_fit_md(&items, Vec::new());
        p.check(&items).unwrap();
        assert_eq!(p.assignments[0], 0);
        assert_eq!(p.assignments[1], 1, "RAM-bound spill");
        assert_eq!(p.assignments[2], 0, "small item backfills bin 0");
    }

    #[test]
    fn reduces_to_scalar_first_fit_on_cpu_only() {
        let sizes = [0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6];
        let md: Vec<VecItem> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| VecItem::new(i as u64, ResourceVec::cpu(s)))
            .collect();
        let scalar: Vec<crate::binpacking::Item> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| crate::binpacking::Item::new(i as u64, s))
            .collect();
        use crate::binpacking::BinPacker;
        let a = first_fit_md(&md, Vec::new());
        let b = crate::binpacking::FirstFit.pack(&scalar, Vec::new());
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn ideal_bins_takes_tightest_dimension() {
        let items = vec![item(0, 0.2, 0.9, 0.1), item(1, 0.2, 0.9, 0.1)];
        // CPU sum 0.4 → 1 bin; RAM sum 1.8 → 2 bins.
        assert_eq!(ideal_bins_md(&items), 2);
    }

    #[test]
    fn prop_no_dimension_overflows() {
        testkit::forall_no_shrink(
            Config::default(),
            |rng| {
                let n = rng.below(60) as usize;
                (0..n)
                    .map(|i| {
                        VecItem::new(
                            i as u64,
                            ResourceVec::new(
                                rng.uniform(0.01, 1.0),
                                rng.uniform(0.0, 1.0),
                                rng.uniform(0.0, 1.0),
                            ),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |items| {
                let p = first_fit_md(items, Vec::new());
                p.check(items).map_err(|e| e)?;
                // Quality: never worse than one bin per item, never better
                // than the per-dimension lower bound.
                let used = p.bins_used();
                let ideal = ideal_bins_md(items);
                if used < ideal {
                    return Err(format!("impossible: used {used} < ideal {ideal}"));
                }
                if used > items.len() {
                    return Err("more bins than items".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "out of [0,1]")]
    fn rejects_oversized_dimension() {
        let _ = item(0, 0.5, 1.2, 0.0);
    }
}
