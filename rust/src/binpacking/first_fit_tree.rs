//! Indexed First-Fit: identical placement decisions to the naive
//! [`FirstFit`](crate::binpacking::FirstFit) scan, in `O(n log m)` instead
//! of `O(n·m)` (§Perf L3 optimization; the naive scan is kept as the
//! reference and the equivalence is property-tested).
//!
//! The index is a max-residual segment tree over bin slots: to place an
//! item, descend left-first into any subtree whose max residual fits — the
//! leftmost (lowest-index) fitting bin, exactly First-Fit's rule. Updates
//! after placement are `O(log m)`.

use super::{Bin, BinPacker, Item, Packing, EPS};

/// Segment tree over bin residuals with leftmost-fit descent.
struct ResidualTree {
    /// Number of leaves (power of two ≥ bins).
    leaves: usize,
    /// `tree[i]` = max residual in the subtree; leaf j at `leaves + j`.
    tree: Vec<f64>,
}

impl ResidualTree {
    fn new(capacity_hint: usize) -> Self {
        let leaves = capacity_hint.next_power_of_two().max(1);
        ResidualTree {
            leaves,
            tree: vec![f64::NEG_INFINITY; 2 * leaves],
        }
    }

    fn set(&mut self, idx: usize, residual: f64) {
        if idx >= self.leaves {
            self.grow(idx + 1);
        }
        let mut i = self.leaves + idx;
        self.tree[i] = residual;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i].max(self.tree[2 * i + 1]);
        }
    }

    fn grow(&mut self, needed: usize) {
        let new_leaves = needed.next_power_of_two();
        let mut new_tree = vec![f64::NEG_INFINITY; 2 * new_leaves];
        for j in 0..self.leaves {
            new_tree[new_leaves + j] = self.tree[self.leaves + j];
        }
        // Rebuild internal nodes.
        for i in (1..new_leaves).rev() {
            new_tree[i] = new_tree[2 * i].max(new_tree[2 * i + 1]);
        }
        self.leaves = new_leaves;
        self.tree = new_tree;
    }

    /// Lowest-index leaf with residual ≥ size − EPS, if any.
    fn first_fit(&self, size: f64) -> Option<usize> {
        let need = size - EPS;
        if self.tree[1] < need {
            return None;
        }
        let mut i = 1;
        while i < self.leaves {
            i = if self.tree[2 * i] >= need { 2 * i } else { 2 * i + 1 };
        }
        Some(i - self.leaves)
    }
}

/// First-Fit with the segment-tree index. Drop-in equivalent of
/// [`FirstFit`](crate::binpacking::FirstFit).
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstFitTree;

impl BinPacker for FirstFitTree {
    fn name(&self) -> &'static str {
        "first-fit-tree"
    }

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
        let mut bins = initial;
        let mut tree = ResidualTree::new((bins.len() + items.len() / 2).max(16));
        for (i, b) in bins.iter().enumerate() {
            tree.set(i, b.residual());
        }
        let mut assignments = Vec::with_capacity(items.len());
        for item in items {
            let idx = match tree.first_fit(item.size) {
                Some(idx) if idx < bins.len() => idx,
                _ => {
                    bins.push(Bin::new());
                    let idx = bins.len() - 1;
                    tree.set(idx, 1.0);
                    idx
                }
            };
            bins[idx].push(*item);
            tree.set(idx, bins[idx].residual());
            assignments.push(idx);
        }
        Packing { assignments, bins }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::FirstFit;
    use crate::testkit::{self, Config};

    fn items(sizes: &[f64]) -> Vec<Item> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| Item::new(i as u64, s))
            .collect()
    }

    #[test]
    fn matches_naive_on_textbook_sequence() {
        let its = items(&[0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6]);
        let naive = FirstFit.pack(&its, Vec::new());
        let tree = FirstFitTree.pack(&its, Vec::new());
        assert_eq!(naive.assignments, tree.assignments);
    }

    #[test]
    fn respects_preexisting_bins() {
        let initial = vec![Bin::with_used(0.95), Bin::with_used(0.2)];
        let its = items(&[0.5, 0.04]);
        let p = FirstFitTree.pack(&its, initial);
        p.check(&its).unwrap();
        assert_eq!(p.assignments[0], 1, "0.5 into the 0.2-loaded bin");
        assert_eq!(p.assignments[1], 0, "0.04 into the 0.95 bin (lowest index)");
    }

    #[test]
    fn tree_grows_beyond_initial_hint() {
        // Force many new bins (every item size 0.9 → one bin each).
        let its = items(&vec![0.9; 200]);
        let p = FirstFitTree.pack(&its, Vec::new());
        p.check(&its).unwrap();
        assert_eq!(p.bins_used(), 200);
    }

    #[test]
    fn prop_equivalent_to_naive_first_fit() {
        // The §Perf optimization must not change any placement decision.
        testkit::forall(
            Config {
                cases: 300,
                ..Config::default()
            },
            |rng| testkit::gen_item_sizes(rng, 120),
            testkit::shrink_f64_vec,
            |sizes| {
                let its = items(sizes);
                let naive = FirstFit.pack(&its, Vec::new());
                let tree = FirstFitTree.pack(&its, Vec::new());
                if naive.assignments == tree.assignments {
                    Ok(())
                } else {
                    Err(format!(
                        "diverged: naive {:?} vs tree {:?}",
                        naive.assignments, tree.assignments
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_equivalent_with_preloaded_bins() {
        testkit::forall_no_shrink(
            Config {
                cases: 200,
                ..Config::default()
            },
            |rng| {
                let loads: Vec<f64> = (0..rng.below(12)).map(|_| rng.uniform(0.0, 1.0)).collect();
                let sizes = testkit::gen_item_sizes(rng, 60);
                (loads, sizes)
            },
            |(loads, sizes)| {
                let its = items(sizes);
                let initial: Vec<Bin> = loads.iter().map(|&u| Bin::with_used(u)).collect();
                let naive = FirstFit.pack(&its, initial.clone());
                let tree = FirstFitTree.pack(&its, initial);
                if naive.assignments == tree.assignments {
                    Ok(())
                } else {
                    Err("diverged with preloaded bins".into())
                }
            },
        );
    }
}
