//! Indexed First-Fit: identical placement decisions to the naive
//! [`FirstFit`](crate::binpacking::FirstFit) scan, in `O(n log m)` instead
//! of `O(n·m)` (§Perf L3 optimization; the naive scan is kept as the
//! reference and the equivalence is property-tested).
//!
//! Historically this module owned its own residual segment tree; that
//! structure now lives in [`index`](crate::binpacking::index) (generalized
//! to the whole Any-Fit family), and [`FirstFitTree`] is a thin wrapper
//! over [`PackEngine`] kept for its established name (`"first-fit-tree"`
//! appears in recorded bench and experiment series).

use super::index::{EngineRule, IndexedPacker, PackEngine};
use super::{Bin, BinPacker, Item, Packing};

/// First-Fit with the segment-tree index. Drop-in equivalent of
/// [`FirstFit`](crate::binpacking::FirstFit).
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstFitTree;

impl BinPacker for FirstFitTree {
    fn name(&self) -> &'static str {
        "first-fit-tree"
    }

    fn pack(&self, items: &[Item], initial: Vec<Bin>) -> Packing {
        PackEngine::new(EngineRule::First, initial).pack_all(items)
    }

    fn pack_one(&self, item: Item, bins: &mut Vec<Bin>) -> usize {
        IndexedPacker::first().pack_one(item, bins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binpacking::FirstFit;
    use crate::testkit::{self, Config};

    fn items(sizes: &[f64]) -> Vec<Item> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| Item::new(i as u64, s))
            .collect()
    }

    #[test]
    fn matches_naive_on_textbook_sequence() {
        let its = items(&[0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.5, 0.1, 0.6]);
        let naive = FirstFit.pack(&its, Vec::new());
        let tree = FirstFitTree.pack(&its, Vec::new());
        assert_eq!(naive.assignments, tree.assignments);
    }

    #[test]
    fn respects_preexisting_bins() {
        let initial = vec![Bin::with_used(0.95), Bin::with_used(0.2)];
        let its = items(&[0.5, 0.04]);
        let p = FirstFitTree.pack(&its, initial);
        p.check(&its).unwrap();
        assert_eq!(p.assignments[0], 1, "0.5 into the 0.2-loaded bin");
        assert_eq!(p.assignments[1], 0, "0.04 into the 0.95 bin (lowest index)");
    }

    #[test]
    fn tree_grows_beyond_initial_hint() {
        // Force many new bins (every item size 0.9 → one bin each).
        let its = items(&vec![0.9; 200]);
        let p = FirstFitTree.pack(&its, Vec::new());
        p.check(&its).unwrap();
        assert_eq!(p.bins_used(), 200);
    }

    #[test]
    fn prop_equivalent_to_naive_first_fit() {
        // The §Perf optimization must not change any placement decision.
        testkit::forall(
            Config {
                cases: 300,
                ..Config::default()
            },
            |rng| testkit::gen_item_sizes(rng, 120),
            testkit::shrink_f64_vec,
            |sizes| {
                let its = items(sizes);
                let naive = FirstFit.pack(&its, Vec::new());
                let tree = FirstFitTree.pack(&its, Vec::new());
                if naive.assignments == tree.assignments {
                    Ok(())
                } else {
                    Err(format!(
                        "diverged: naive {:?} vs tree {:?}",
                        naive.assignments, tree.assignments
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_equivalent_with_preloaded_bins() {
        testkit::forall_no_shrink(
            Config {
                cases: 200,
                ..Config::default()
            },
            |rng| {
                let loads: Vec<f64> = (0..rng.below(12)).map(|_| rng.uniform(0.0, 1.0)).collect();
                let sizes = testkit::gen_item_sizes(rng, 60);
                (loads, sizes)
            },
            |(loads, sizes)| {
                let its = items(sizes);
                let initial: Vec<Bin> = loads.iter().map(|&u| Bin::with_used(u)).collect();
                let naive = FirstFit.pack(&its, initial.clone());
                let tree = FirstFitTree.pack(&its, initial);
                if naive.assignments == tree.assignments {
                    Ok(())
                } else {
                    Err("diverged with preloaded bins".into())
                }
            },
        );
    }
}
