//! Metrics: time-series recording, CSV emission and ASCII rendering.
//!
//! Every experiment records per-worker scheduled/measured CPU, queue
//! lengths and worker counts here, then emits (a) a long-format CSV
//! (`series,t_ms,value`) consumed by any plotting tool and (b) an ASCII
//! rendering so `repro experiment figN` shows the figure's shape directly
//! in the terminal. Error series (Figs 5/9) are computed from pairs of
//! recorded series.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::types::Millis;

/// One named time series.
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    pub points: Vec<(Millis, f64)>,
}

impl TimeSeries {
    pub fn push(&mut self, t: Millis, v: f64) {
        debug_assert!(
            self.points.last().map(|(pt, _)| *pt <= t).unwrap_or(true),
            "time series must be appended in order"
        );
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Value at or before `t` (step interpolation).
    pub fn at(&self, t: Millis) -> Option<f64> {
        match self.points.binary_search_by(|(pt, _)| pt.cmp(&t)) {
            Ok(i) => Some(self.points[i].1),
            Err(0) => None,
            Err(i) => Some(self.points[i - 1].1),
        }
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|(_, v)| *v).fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|(_, v)| *v).sum::<f64>() / self.points.len() as f64
    }

    /// Last time with a point.
    pub fn end(&self) -> Option<Millis> {
        self.points.last().map(|(t, _)| *t)
    }
}

/// Handle to an interned series name. Obtained once from
/// [`Recorder::series_id`]; recording through it ([`Recorder::record_id`])
/// touches no `String` at all, so steady-state sampling is allocation-free
/// apart from the appended points themselves. Ids are only meaningful for
/// the recorder that issued them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SeriesId(u32);

/// A set of named series recorded during one experiment run.
///
/// Names are interned: the name→id map is consulted (without allocating)
/// on every `record` call, and a name is copied into the map exactly once
/// — the first time it is seen. Callers on hot paths should intern up
/// front and use [`Recorder::record_id`].
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    names: BTreeMap<String, SeriesId>,
    data: Vec<TimeSeries>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Intern `name`, allocating only if it has never been seen.
    pub fn series_id(&mut self, name: &str) -> SeriesId {
        if let Some(id) = self.names.get(name) {
            return *id;
        }
        let id = SeriesId(u32::try_from(self.data.len()).unwrap_or(u32::MAX));
        assert!(
            (id.0 as usize) == self.data.len(),
            "series count exceeds u32 interner range"
        );
        self.names.insert(name.to_string(), id);
        self.data.push(TimeSeries::default());
        id
    }

    /// Append a point to an interned series. Allocation-free except for
    /// the point storage itself.
    pub fn record_id(&mut self, id: SeriesId, t: Millis, v: f64) {
        self.data[id.0 as usize].push(t, v);
    }

    pub fn record(&mut self, name: &str, t: Millis, v: f64) {
        let id = self.series_id(name);
        self.record_id(id, t, v);
    }

    pub fn get(&self, name: &str) -> Option<&TimeSeries> {
        self.names.get(name).map(|id| &self.data[id.0 as usize])
    }

    pub fn names(&self) -> Vec<&str> {
        self.names.keys().map(|s| s.as_str()).collect()
    }

    /// Pointwise difference `a - b` sampled at `a`'s timestamps — the
    /// paper's error-in-percentage-points series (scheduled vs measured).
    pub fn error_series(&self, a: &str, b: &str) -> TimeSeries {
        let mut out = TimeSeries::default();
        let (Some(sa), Some(sb)) = (self.get(a), self.get(b)) else {
            return out;
        };
        for (t, va) in &sa.points {
            if let Some(vb) = sb.at(*t) {
                out.push(*t, va - vb);
            }
        }
        out
    }

    /// Long-format CSV: `series,t_ms,value`. Series are emitted in name
    /// order (the interner map is a `BTreeMap`), so output is independent
    /// of interning order.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,t_ms,value\n");
        for (name, id) in &self.names {
            let s = &self.data[id.0 as usize];
            for (t, v) in &s.points {
                let _ = writeln!(out, "{name},{},{v:.6}", t.0);
            }
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// ASCII rendering of selected series on a shared time axis: one row
    /// block per series, `width` buckets, `#`-scaled by value (0..max).
    pub fn ascii_chart(&self, names: &[&str], width: usize, height: usize) -> String {
        let mut out = String::new();
        let t_end = names
            .iter()
            .filter_map(|n| self.get(n).and_then(|s| s.end()))
            .max()
            .unwrap_or(Millis::ZERO);
        let v_max = names
            .iter()
            .filter_map(|n| self.get(n).map(|s| s.max()))
            .fold(0.0f64, f64::max)
            .max(1e-9);
        for name in names {
            let Some(s) = self.get(name) else { continue };
            // Bucket means over the time axis.
            let mut buckets = vec![(0.0f64, 0u32); width];
            for (t, v) in &s.points {
                let idx = if t_end.0 == 0 {
                    0
                } else {
                    ((t.0 as u128 * (width as u128 - 1)) / t_end.0 as u128) as usize
                };
                buckets[idx].0 += *v;
                buckets[idx].1 += 1;
            }
            let vals: Vec<f64> = buckets
                .iter()
                .map(|(sum, n)| if *n > 0 { sum / *n as f64 } else { f64::NAN })
                .collect();
            let _ = writeln!(out, "{name}  (max {v_max:.2})");
            for row in (1..=height).rev() {
                let threshold = v_max * row as f64 / height as f64;
                let line: String = vals
                    .iter()
                    .map(|v| {
                        if v.is_nan() {
                            ' '
                        } else if *v >= threshold - v_max / (2.0 * height as f64) {
                            '#'
                        } else {
                            ' '
                        }
                    })
                    .collect();
                let _ = writeln!(out, "  |{line}");
            }
            let _ = writeln!(out, "  +{}", "-".repeat(width));
            let _ = writeln!(
                out,
                "   0{:>width$.0}s",
                t_end.as_secs_f64(),
                width = width - 1
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_get() {
        let mut r = Recorder::new();
        r.record("w0.cpu", Millis(0), 0.5);
        r.record("w0.cpu", Millis(1000), 0.8);
        let s = r.get("w0.cpu").unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.max(), 0.8);
        assert!((s.mean() - 0.65).abs() < 1e-12);
    }

    #[test]
    fn step_interpolation() {
        let mut s = TimeSeries::default();
        s.push(Millis(100), 1.0);
        s.push(Millis(200), 2.0);
        assert_eq!(s.at(Millis(50)), None);
        assert_eq!(s.at(Millis(100)), Some(1.0));
        assert_eq!(s.at(Millis(150)), Some(1.0));
        assert_eq!(s.at(Millis(200)), Some(2.0));
        assert_eq!(s.at(Millis(999)), Some(2.0));
    }

    #[test]
    fn error_series_is_pointwise_diff() {
        let mut r = Recorder::new();
        for t in [0u64, 1000, 2000] {
            r.record("sched", Millis(t), 0.9);
            r.record("meas", Millis(t), 0.8);
        }
        let err = r.error_series("sched", "meas");
        assert_eq!(err.len(), 3);
        for (_, v) in &err.points {
            assert!((v - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn error_series_missing_input_empty() {
        let r = Recorder::new();
        assert!(r.error_series("a", "b").is_empty());
    }

    #[test]
    fn csv_format() {
        let mut r = Recorder::new();
        r.record("a", Millis(0), 1.0);
        r.record("b", Millis(500), 0.25);
        let csv = r.to_csv();
        assert!(csv.starts_with("series,t_ms,value\n"));
        assert!(csv.contains("a,0,1.000000"));
        assert!(csv.contains("b,500,0.250000"));
    }

    #[test]
    fn interned_ids_are_stable_and_equivalent_to_names() {
        let mut r = Recorder::new();
        let a = r.series_id("a");
        let b = r.series_id("b");
        assert_ne!(a, b);
        assert_eq!(r.series_id("a"), a, "re-interning returns the same id");
        r.record_id(a, Millis(0), 1.0);
        r.record("a", Millis(100), 2.0);
        let s = r.get("a").unwrap();
        assert_eq!(s.points, vec![(Millis(0), 1.0), (Millis(100), 2.0)]);
        assert!(r.get("b").unwrap().is_empty());
    }

    #[test]
    fn csv_order_is_by_name_not_interning_order() {
        let mut r = Recorder::new();
        let z = r.series_id("z");
        let a = r.series_id("a");
        r.record_id(z, Millis(0), 1.0);
        r.record_id(a, Millis(0), 2.0);
        let csv = r.to_csv();
        let a_pos = csv.find("a,0").unwrap();
        let z_pos = csv.find("z,0").unwrap();
        assert!(a_pos < z_pos, "CSV must stay name-sorted:\n{csv}");
    }

    #[test]
    fn ascii_chart_renders() {
        let mut r = Recorder::new();
        for t in 0..100 {
            r.record("ramp", Millis(t * 100), t as f64 / 100.0);
        }
        let chart = r.ascii_chart(&["ramp"], 40, 5);
        assert!(chart.contains("ramp"));
        assert!(chart.contains('#'));
        // The ramp should touch the top only near the right edge.
        let top_row = chart.lines().nth(1).unwrap();
        assert!(top_row.trim_end().ends_with('#'));
    }

    #[test]
    #[should_panic(expected = "order")]
    #[cfg(debug_assertions)]
    fn out_of_order_push_asserts() {
        let mut s = TimeSeries::default();
        s.push(Millis(100), 1.0);
        s.push(Millis(50), 2.0);
    }
}
