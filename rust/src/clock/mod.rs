//! Virtual/real time. All coordinator logic takes time as a [`Millis`]
//! argument or a [`Clock`] handle, so the same code drives the
//! discrete-time experiments (instant) and the real-time deployment mode.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::types::Millis;

/// A monotonic time source.
pub trait Clock: Send + Sync {
    /// Current time since the clock's epoch.
    fn now(&self) -> Millis;
    /// Block the calling thread for `d` (no-op under simulation: virtual
    /// time is advanced by the simulation loop, not by sleepers).
    fn sleep(&self, d: Millis);
}

/// Wall-clock time relative to construction.
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock {
            epoch: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Millis {
        Millis(self.epoch.elapsed().as_millis() as u64)
    }

    fn sleep(&self, d: Millis) {
        std::thread::sleep(std::time::Duration::from_millis(d.0));
    }
}

/// Shared virtual clock, advanced explicitly by the simulation driver.
#[derive(Clone)]
pub struct SimClock {
    now_ms: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock {
            now_ms: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Advance virtual time by `d`.
    pub fn advance(&self, d: Millis) {
        self.now_ms.fetch_add(d.0, Ordering::SeqCst);
    }

    /// Jump to an absolute virtual time (must not go backwards).
    pub fn set(&self, t: Millis) {
        let prev = self.now_ms.swap(t.0, Ordering::SeqCst);
        debug_assert!(prev <= t.0, "sim clock moved backwards: {prev} -> {}", t.0);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Millis {
        Millis(self.now_ms.load(Ordering::SeqCst))
    }

    fn sleep(&self, _d: Millis) {
        // Virtual time is advanced by the driver, never by sleeping.
    }
}

/// A recurring timer: fires whenever at least `period` has elapsed since the
/// last firing. This is how every periodic control loop in the system (the
/// bin-packing run rate, profiler report interval, load-predictor polling)
/// expresses its cadence without owning a thread.
#[derive(Clone, Copy, Debug)]
pub struct Periodic {
    period: Millis,
    last: Option<Millis>,
}

impl Periodic {
    pub fn new(period: Millis) -> Self {
        assert!(period.0 > 0, "period must be positive");
        Periodic { period, last: None }
    }

    /// Returns true (and re-arms) if the period elapsed. The first call
    /// always fires, anchoring the cadence at the caller's start time.
    ///
    /// Re-arming advances the anchor by **whole periods**, not to the
    /// observation time: under a coarse tick (e.g. a 1000 ms period
    /// sampled every 400 ms) anchoring at `now` would drift the cadence
    /// to 0, 1200, 2400 ms — a 20 % stretch that biases every
    /// rate-of-change computed from the fired samples (the load
    /// predictor's ROC). Whole-period advancement keeps the long-run rate
    /// at exactly one firing per period.
    pub fn fire(&mut self, now: Millis) -> bool {
        match self.last {
            None => {
                self.last = Some(now);
                true
            }
            Some(last) if now.0 >= last.0 + self.period.0 => {
                let whole = (now.0 - last.0) / self.period.0;
                self.last = Some(Millis(last.0 + whole * self.period.0));
                true
            }
            _ => false,
        }
    }

    pub fn period(&self) -> Millis {
        self.period
    }

    /// Reset so the next `fire` triggers immediately.
    pub fn reset(&mut self) {
        self.last = None;
    }

    /// Earliest time at which `fire` would return true, or `None` if the
    /// timer has never fired (in which case any call fires immediately).
    /// This is the timer's contribution to an event-wheel deadline: a
    /// caller that next observes the timer at exactly `next_fire()` sees
    /// the same firing (and the same whole-period re-anchor) as one that
    /// polled it every tick, because `fire` anchors on the *grid*, not on
    /// the observation time.
    pub fn next_fire(&self) -> Option<Millis> {
        self.last.map(|l| Millis(l.0 + self.period.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn sim_clock_advances_only_explicitly() {
        let c = SimClock::new();
        assert_eq!(c.now(), Millis(0));
        c.sleep(Millis(1000)); // no-op
        assert_eq!(c.now(), Millis(0));
        c.advance(Millis(250));
        assert_eq!(c.now(), Millis(250));
        c.set(Millis(1000));
        assert_eq!(c.now(), Millis(1000));
    }

    #[test]
    fn sim_clock_shared_between_clones() {
        let a = SimClock::new();
        let b = a.clone();
        a.advance(Millis(10));
        assert_eq!(b.now(), Millis(10));
    }

    #[test]
    fn periodic_fires_on_schedule() {
        let mut p = Periodic::new(Millis(100));
        assert!(p.fire(Millis(0)), "first call fires");
        assert!(!p.fire(Millis(50)));
        assert!(!p.fire(Millis(99)));
        assert!(p.fire(Millis(100)));
        assert!(!p.fire(Millis(150)));
        assert!(p.fire(Millis(210)));
    }

    #[test]
    fn periodic_coarse_tick_does_not_drift() {
        // Regression: a 1000 ms period sampled on a 400 ms tick used to
        // re-anchor at the observation time and fire at 0, 1200, 2400 …
        // (a 20 % cadence stretch). Whole-period re-arming keeps the
        // long-run rate at one firing per period.
        let mut p = Periodic::new(Millis(1000));
        let mut fires = Vec::new();
        let mut t = 0;
        while t <= 12_000 {
            if p.fire(Millis(t)) {
                fires.push(t);
            }
            t += 400;
        }
        // 13 firings over [0, 12 s] at a 1 s period (the drifting
        // implementation managed only 11).
        assert_eq!(fires.len(), 13, "fires at {fires:?}");
        assert_eq!(fires.first(), Some(&0));
        assert_eq!(fires.last(), Some(&12_000));
        // No observation-time anchoring: gaps average exactly one period.
        let span = fires.last().unwrap() - fires.first().unwrap();
        assert_eq!(span / (fires.len() as u64 - 1), 1000);
    }

    #[test]
    fn periodic_skips_missed_periods_without_bursting() {
        // A long stall must not cause catch-up firings: one fire, anchor
        // advanced by whole periods past the stall.
        let mut p = Periodic::new(Millis(100));
        assert!(p.fire(Millis(0)));
        assert!(p.fire(Millis(1050)), "stall of 10.5 periods fires once");
        assert!(!p.fire(Millis(1060)));
        assert!(p.fire(Millis(1100)), "cadence stays on the 100 ms grid");
    }

    #[test]
    fn next_fire_matches_fire_semantics() {
        let mut p = Periodic::new(Millis(100));
        assert_eq!(p.next_fire(), None, "unanchored timer fires on any call");
        assert!(p.fire(Millis(40)));
        assert_eq!(p.next_fire(), Some(Millis(140)));
        // Observing exactly at next_fire() fires and stays on the grid.
        assert!(p.fire(Millis(140)));
        assert_eq!(p.next_fire(), Some(Millis(240)));
        // A late observation fires once and re-arms on the same grid, so
        // next_fire is always a grid point (140 + k*100).
        assert!(p.fire(Millis(555)));
        assert_eq!(p.next_fire(), Some(Millis(640)));
    }

    #[test]
    fn periodic_reset() {
        let mut p = Periodic::new(Millis(100));
        assert!(p.fire(Millis(0)));
        p.reset();
        assert!(p.fire(Millis(1)));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_period_panics() {
        let _ = Periodic::new(Millis(0));
    }
}
