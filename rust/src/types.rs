//! Core domain types shared across the whole stack.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Sub};
use std::sync::Arc;

/// Milliseconds of (virtual or real) time since an arbitrary epoch.
///
/// All coordinator logic is expressed against this type so it is agnostic to
/// whether it runs under the simulated clock or wall time.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default, Debug)]
pub struct Millis(pub u64);

impl Millis {
    pub const ZERO: Millis = Millis(0);

    pub fn from_secs(s: u64) -> Self {
        Millis(s * 1000)
    }

    pub fn from_secs_f64(s: f64) -> Self {
        Millis(crate::util::cast::f64_to_u64((s.max(0.0) * 1000.0).round()))
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn saturating_sub(self, rhs: Millis) -> Millis {
        Millis(self.0.saturating_sub(rhs.0))
    }

    pub fn min(self, rhs: Millis) -> Millis {
        Millis(self.0.min(rhs.0))
    }

    pub fn max(self, rhs: Millis) -> Millis {
        Millis(self.0.max(rhs.0))
    }
}

impl Add for Millis {
    type Output = Millis;
    fn add(self, rhs: Millis) -> Millis {
        Millis(self.0 + rhs.0)
    }
}

impl AddAssign for Millis {
    fn add_assign(&mut self, rhs: Millis) {
        self.0 += rhs.0;
    }
}

impl Sub for Millis {
    type Output = Millis;
    fn sub(self, rhs: Millis) -> Millis {
        Millis(self.0.saturating_sub(rhs.0))
    }
}

impl Mul<u64> for Millis {
    type Output = Millis;
    fn mul(self, rhs: u64) -> Millis {
        Millis(self.0 * rhs)
    }
}

impl Div<u64> for Millis {
    type Output = Millis;
    fn div(self, rhs: u64) -> Millis {
        Millis(self.0 / rhs)
    }
}

impl fmt::Display for Millis {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

/// Fraction of a worker VM's total CPU capacity, in `[0, +)`.
///
/// `1.0` is the whole VM (the bin capacity of the paper's model); a
/// single-core PE on an 8-core SSC.xlarge worker is `0.125`. Values are
/// clamped non-negative but deliberately *not* clamped at 1.0: measured
/// usage can transiently exceed the nominal capacity (OS noise), which the
/// error figures (Figs 5/9) must be able to express.
#[derive(Clone, Copy, PartialEq, PartialOrd, Default, Debug)]
pub struct CpuFraction(pub f64);

impl CpuFraction {
    pub const ZERO: CpuFraction = CpuFraction(0.0);
    pub const FULL: CpuFraction = CpuFraction(1.0);

    pub fn new(v: f64) -> Self {
        CpuFraction(v.max(0.0))
    }

    pub fn value(self) -> f64 {
        self.0
    }

    pub fn clamp01(self) -> Self {
        CpuFraction(self.0.clamp(0.0, 1.0))
    }

    /// Percentage points, the unit of the paper's error plots.
    pub fn as_percent(self) -> f64 {
        self.0 * 100.0
    }
}

impl Add for CpuFraction {
    type Output = CpuFraction;
    fn add(self, rhs: CpuFraction) -> CpuFraction {
        CpuFraction(self.0 + rhs.0)
    }
}

impl AddAssign for CpuFraction {
    fn add_assign(&mut self, rhs: CpuFraction) {
        self.0 += rhs.0;
    }
}

impl Sub for CpuFraction {
    type Output = CpuFraction;
    fn sub(self, rhs: CpuFraction) -> CpuFraction {
        CpuFraction(self.0 - rhs.0)
    }
}

impl fmt::Display for CpuFraction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1}%", self.as_percent())
    }
}

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $prefix:literal) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
        pub struct $name(pub u64);

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }
    };
}

id_type!(
    /// A processing-engine (container) instance.
    PeId,
    "pe-"
);
id_type!(
    /// A worker node (one per hosting VM).
    WorkerId,
    "w-"
);
id_type!(
    /// A cloud VM (workers run on VMs; the distinction matters during boot).
    VmId,
    "vm-"
);
id_type!(
    /// A streamed message (one large object, e.g. one microscopy image).
    MessageId,
    "msg-"
);

/// A Docker-image-like identifier for the PE container a message needs.
///
/// The paper's stream request carries "the docker container and tag that a
/// PE needs to run to process the data"; we keep the same shape.
///
/// Internally an `Arc<str>`: image names are cloned on every routing
/// decision, worker report, cluster-view rebuild and pull-cache probe, so
/// `clone` must be a refcount bump, not a heap copy (§Perf — the simulator
/// tick used to allocate a string per hosted PE per tick). Equality,
/// ordering and hashing follow the string contents.
#[derive(Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct ImageName(Arc<str>);

impl ImageName {
    pub fn new(s: impl Into<String>) -> Self {
        ImageName(Arc::from(s.into()))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for ImageName {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for ImageName {
    fn from(s: &str) -> Self {
        ImageName(Arc::from(s))
    }
}

/// One streamed message: the unit of work a PE processes.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamMessage {
    pub id: MessageId,
    /// Container image that must process this message.
    pub image: ImageName,
    /// Size of the object in bytes (MB-scale for microscopy images).
    pub payload_bytes: u64,
    /// Intrinsic service demand in CPU-milliseconds on one dedicated core.
    /// In simulation this drives the processing time; in real mode it is
    /// ignored (the PJRT execution provides the real cost).
    pub service_demand: Millis,
    /// When the message entered the system (for latency accounting).
    pub created_at: Millis,
}

/// Counter-based id generator (no global state; own one per subsystem).
#[derive(Default, Debug, Clone)]
pub struct IdGen {
    next: u64,
}

impl IdGen {
    pub fn new() -> Self {
        IdGen { next: 0 }
    }

    pub fn next_id(&mut self) -> u64 {
        let id = self.next;
        self.next += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn millis_arithmetic() {
        let a = Millis::from_secs(2);
        let b = Millis(500);
        assert_eq!((a + b).0, 2500);
        assert_eq!((a - b).0, 1500);
        assert_eq!((b - a).0, 0, "sub saturates");
        assert_eq!((b * 4).0, 2000);
        assert_eq!((a / 2).0, 1000);
    }

    #[test]
    fn millis_float_roundtrip() {
        let m = Millis::from_secs_f64(1.2345);
        assert!((m.as_secs_f64() - 1.2345).abs() <= 5e-4 + 1e-12);
        assert_eq!(Millis::from_secs_f64(-5.0), Millis::ZERO);
    }

    #[test]
    fn cpu_fraction_clamps_negative_only() {
        assert_eq!(CpuFraction::new(-0.5).value(), 0.0);
        assert_eq!(CpuFraction::new(1.5).value(), 1.5);
        assert_eq!(CpuFraction::new(1.5).clamp01().value(), 1.0);
    }

    #[test]
    fn cpu_fraction_percent() {
        assert_eq!(CpuFraction(0.42).as_percent(), 42.0);
    }

    #[test]
    fn id_display() {
        assert_eq!(PeId(3).to_string(), "pe-3");
        assert_eq!(WorkerId(0).to_string(), "w-0");
        assert_eq!(VmId(7).to_string(), "vm-7");
    }

    #[test]
    fn image_name_clone_shares_storage() {
        let a = ImageName::new("nuclei:latest");
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.0, &b.0), "clone must be a refcount bump");
        assert_eq!(a, b);
        assert_eq!(a.as_str(), "nuclei:latest");
        assert_eq!(ImageName::from("x"), ImageName::new("x"));
    }

    #[test]
    fn idgen_monotonic() {
        let mut g = IdGen::new();
        assert_eq!(g.next_id(), 0);
        assert_eq!(g.next_id(), 1);
        assert_eq!(g.next_id(), 2);
    }
}
