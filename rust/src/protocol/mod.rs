//! Wire protocol: the message vocabulary exchanged between the stream
//! connector, master and workers, plus JSON encode/decode for the TCP
//! deployment mode. The simulation mode passes these same structs in
//! memory, so both modes exercise identical semantics.

pub mod messages;

pub use messages::*;
