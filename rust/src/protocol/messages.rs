//! Protocol messages (HIO REST-API analogue).
//!
//! The paper's stream request "consists of both the data to be processed,
//! and the docker container and tag that a PE needs to run to process the
//! data"; worker nodes "report to the Master node". These types carry that
//! same information, with JSON (de)serialization for the TCP mode.

use crate::binpacking::{Resource, ResourceVec};
use crate::types::{CpuFraction, ImageName, MessageId, Millis, PeId, StreamMessage, WorkerId};
use crate::util::json::Json;

/// Lifecycle state of a PE as reported to the master.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PeState {
    /// Container is starting (docker pull/start latency).
    Booting,
    /// Ready to accept a message.
    Idle,
    /// Processing a message.
    Busy,
    /// Graceful shutdown in progress (docker stop latency): no longer
    /// schedulable, still burning cleanup CPU.
    Stopping,
    /// Shut down (idle self-termination or explicit stop).
    Terminated,
}

impl PeState {
    pub fn as_str(self) -> &'static str {
        match self {
            PeState::Booting => "booting",
            PeState::Idle => "idle",
            PeState::Busy => "busy",
            PeState::Stopping => "stopping",
            PeState::Terminated => "terminated",
        }
    }

    pub fn parse(s: &str) -> Option<PeState> {
        Some(match s {
            "booting" => PeState::Booting,
            "idle" => PeState::Idle,
            "busy" => PeState::Busy,
            "stopping" => PeState::Stopping,
            "terminated" => PeState::Terminated,
            _ => return None,
        })
    }
}

/// Per-PE status inside a worker report.
#[derive(Clone, Debug, PartialEq)]
pub struct PeStatus {
    pub pe: PeId,
    pub image: ImageName,
    pub state: PeState,
    /// CPU fraction this PE consumed over the report interval.
    pub cpu: CpuFraction,
}

/// Periodic report each worker sends to the master (the worker half of the
/// paper's worker profiler, §V-B3 — extended to the full resource vector).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerReport {
    pub worker: WorkerId,
    pub at: Millis,
    /// Total measured CPU over the interval (0..1 of the whole VM).
    pub total_cpu: CpuFraction,
    /// Average measured usage per container image across that image's
    /// PEs: CPU as a fraction of this worker, RAM/net in reference-VM
    /// units. CPU-only deployments simply report zero RAM/net (the
    /// master-side profiler filters them below its per-dimension busy
    /// floors).
    pub per_image: Vec<(ImageName, ResourceVec)>,
    /// Furthest checkpointed progress fraction per image (0..=1), from
    /// the worker's periodic checkpointer. Empty when checkpointing is
    /// disabled — and absent on the wire, so legacy peers interoperate
    /// in both directions (an absent key parses as empty; a present but
    /// malformed one rejects the report).
    pub progress: Vec<(ImageName, f64)>,
    pub pes: Vec<PeStatus>,
}

impl WorkerReport {
    pub fn idle_pes(&self, image: &ImageName) -> usize {
        self.pes
            .iter()
            .filter(|p| p.state == PeState::Idle && &p.image == image)
            .count()
    }
}

/// Commands the coordination layer issues to workers. In the simulation the
/// cluster harness applies them directly; over TCP they are serialized.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkerCommand {
    /// Start a PE container for `image` (the allocator's hosting decision).
    StartPe { image: ImageName },
    /// Deliver a message to a specific PE (P2P from connector, or backlog
    /// drain from the master).
    Deliver { pe: PeId, msg: StreamMessage },
    /// Gracefully stop a PE.
    StopPe { pe: PeId },
}

/// Connector-facing responses from the master's endpoint query.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteDecision {
    /// Send P2P to this worker/PE.
    Direct { worker: WorkerId, pe: PeId },
    /// No capacity: the message was accepted into the master's backlog.
    Queued { backlog_len: usize },
}

// ---------- JSON encoding (TCP mode) ----------

impl PeStatus {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("pe", Json::num(self.pe.0 as f64)),
            ("image", Json::str(self.image.as_str())),
            ("state", Json::str(self.state.as_str())),
            ("cpu", Json::num(self.cpu.value())),
        ])
    }

    pub fn from_json(v: &Json) -> Option<PeStatus> {
        Some(PeStatus {
            pe: PeId(v.get("pe")?.as_u64()?),
            image: ImageName::new(v.get("image")?.as_str()?),
            state: PeState::parse(v.get("state")?.as_str()?)?,
            cpu: CpuFraction::new(v.get("cpu")?.as_f64()?),
        })
    }
}

impl WorkerReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("worker", Json::num(self.worker.0 as f64)),
            ("at", Json::num(self.at.0 as f64)),
            ("total_cpu", Json::num(self.total_cpu.value())),
            (
                "per_image",
                Json::arr(self.per_image.iter().map(|(img, usage)| {
                    Json::obj([
                        ("image", Json::str(img.as_str())),
                        ("cpu", Json::num(usage.get(Resource::Cpu))),
                        ("ram", Json::num(usage.get(Resource::Ram))),
                        ("net", Json::num(usage.get(Resource::Net))),
                    ])
                })),
            ),
        ];
        // Only checkpointing workers emit the key: checkpoint-free
        // reports stay byte-identical to the legacy wire format.
        if !self.progress.is_empty() {
            fields.push((
                "progress",
                Json::arr(self.progress.iter().map(|(img, frac)| {
                    Json::obj([
                        ("image", Json::str(img.as_str())),
                        ("frac", Json::num(*frac)),
                    ])
                })),
            ));
        }
        fields.push(("pes", Json::arr(self.pes.iter().map(|p| p.to_json()))));
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Option<WorkerReport> {
        let per_image = v
            .get("per_image")?
            .as_arr()?
            .iter()
            .map(|e| {
                // RAM/net are optional on the wire: reports from CPU-only
                // peers (the pre-vector protocol) parse as zero-RAM/net.
                // A key that is *present* must be numeric, though — a
                // malformed value rejects the report like a malformed cpu
                // would, instead of silently reading as "no demand".
                let dim = |key: &str| match e.get(key) {
                    None => Some(0.0),
                    Some(j) => j.as_f64(),
                };
                Some((
                    ImageName::new(e.get("image")?.as_str()?),
                    ResourceVec::new(e.get("cpu")?.as_f64()?, dim("ram")?, dim("net")?),
                ))
            })
            .collect::<Option<Vec<_>>>()?;
        // Checkpoint progress is optional on the wire (absent from
        // checkpoint-free and legacy peers → empty), but a key that is
        // present must be well-formed — a corrupt entry rejects the
        // report instead of silently dropping restart state.
        let progress = match v.get("progress") {
            None => Vec::new(),
            Some(j) => j
                .as_arr()?
                .iter()
                .map(|e| {
                    Some((
                        ImageName::new(e.get("image")?.as_str()?),
                        e.get("frac")?.as_f64()?,
                    ))
                })
                .collect::<Option<Vec<_>>>()?,
        };
        let pes = v
            .get("pes")?
            .as_arr()?
            .iter()
            .map(PeStatus::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(WorkerReport {
            worker: WorkerId(v.get("worker")?.as_u64()?),
            at: Millis(v.get("at")?.as_u64()?),
            total_cpu: CpuFraction::new(v.get("total_cpu")?.as_f64()?),
            per_image,
            progress,
            pes,
        })
    }
}

impl StreamMessage {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("id", Json::num(self.id.0 as f64)),
            ("image", Json::str(self.image.as_str())),
            ("payload_bytes", Json::num(self.payload_bytes as f64)),
            ("service_demand", Json::num(self.service_demand.0 as f64)),
            ("created_at", Json::num(self.created_at.0 as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<StreamMessage> {
        Some(StreamMessage {
            id: MessageId(v.get("id")?.as_u64()?),
            image: ImageName::new(v.get("image")?.as_str()?),
            payload_bytes: v.get("payload_bytes")?.as_u64()?,
            service_demand: Millis(v.get("service_demand")?.as_u64()?),
            created_at: Millis(v.get("created_at")?.as_u64()?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> WorkerReport {
        WorkerReport {
            worker: WorkerId(2),
            at: Millis(5000),
            total_cpu: CpuFraction::new(0.62),
            per_image: vec![
                (
                    ImageName::new("cellprofiler"),
                    ResourceVec::new(0.12, 0.25, 0.04),
                ),
                (ImageName::new("busy"), ResourceVec::cpu(0.25)),
            ],
            progress: vec![(ImageName::new("cellprofiler"), 0.4)],
            pes: vec![
                PeStatus {
                    pe: PeId(1),
                    image: ImageName::new("cellprofiler"),
                    state: PeState::Busy,
                    cpu: CpuFraction::new(0.13),
                },
                PeStatus {
                    pe: PeId(2),
                    image: ImageName::new("cellprofiler"),
                    state: PeState::Idle,
                    cpu: CpuFraction::new(0.004),
                },
            ],
        }
    }

    #[test]
    fn pe_state_roundtrip() {
        for s in [
            PeState::Booting,
            PeState::Idle,
            PeState::Busy,
            PeState::Stopping,
            PeState::Terminated,
        ] {
            assert_eq!(PeState::parse(s.as_str()), Some(s));
        }
        assert_eq!(PeState::parse("bogus"), None);
    }

    #[test]
    fn report_json_roundtrip() {
        let r = sample_report();
        let j = r.to_json();
        let parsed = WorkerReport::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn stream_message_json_roundtrip() {
        let m = StreamMessage {
            id: MessageId(77),
            image: ImageName::new("nuclei"),
            payload_bytes: 3 * 1024 * 1024,
            service_demand: Millis(15_000),
            created_at: Millis(42),
        };
        let parsed =
            StreamMessage::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed.id, m.id);
        assert_eq!(parsed.image, m.image);
        assert_eq!(parsed.payload_bytes, m.payload_bytes);
        assert_eq!(parsed.service_demand, m.service_demand);
    }

    #[test]
    fn idle_pes_counts_per_image() {
        let r = sample_report();
        assert_eq!(r.idle_pes(&ImageName::new("cellprofiler")), 1);
        assert_eq!(r.idle_pes(&ImageName::new("busy")), 0);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let j = Json::parse(r#"{"worker": 1}"#).unwrap();
        assert!(WorkerReport::from_json(&j).is_none());
    }

    #[test]
    fn per_image_parses_legacy_cpu_only_entries() {
        // A report from a pre-vector peer carries no ram/net keys: it must
        // parse as zero RAM/net, not be rejected.
        let j = Json::parse(
            r#"{"worker": 1, "at": 0, "total_cpu": 0.5,
                "per_image": [{"image": "img", "cpu": 0.25}], "pes": []}"#,
        )
        .unwrap();
        let r = WorkerReport::from_json(&j).expect("legacy entry parses");
        let (img, usage) = &r.per_image[0];
        assert_eq!(img.as_str(), "img");
        assert_eq!(usage.get(Resource::Cpu), 0.25);
        assert_eq!(usage.get(Resource::Ram), 0.0);
        assert_eq!(usage.get(Resource::Net), 0.0);
    }

    #[test]
    fn progress_absent_parses_as_empty_and_roundtrips_away() {
        // Legacy / checkpoint-free reports carry no "progress" key.
        let j = Json::parse(
            r#"{"worker": 1, "at": 0, "total_cpu": 0.5,
                "per_image": [{"image": "img", "cpu": 0.25}], "pes": []}"#,
        )
        .unwrap();
        let r = WorkerReport::from_json(&j).expect("legacy report parses");
        assert!(r.progress.is_empty());
        // And an empty progress vec stays off the wire entirely.
        assert!(!r.to_json().to_string().contains("progress"));
    }

    #[test]
    fn progress_malformed_rejects_the_report() {
        // A present "progress" key must be well-formed: a non-numeric
        // fraction is corruption, not a legacy peer.
        let j = Json::parse(
            r#"{"worker": 1, "at": 0, "total_cpu": 0.5,
                "per_image": [{"image": "img", "cpu": 0.25}],
                "progress": [{"image": "img", "frac": "oops"}], "pes": []}"#,
        )
        .unwrap();
        assert!(WorkerReport::from_json(&j).is_none());
        let j = Json::parse(
            r#"{"worker": 1, "at": 0, "total_cpu": 0.5,
                "per_image": [{"image": "img", "cpu": 0.25}],
                "progress": 7, "pes": []}"#,
        )
        .unwrap();
        assert!(WorkerReport::from_json(&j).is_none());
    }

    #[test]
    fn per_image_rejects_malformed_present_dimensions() {
        // Absent ram/net keys are the legacy protocol; a *present* but
        // non-numeric value is corruption and must reject the report —
        // reading it as 0 would silently pin the image to its prior.
        let j = Json::parse(
            r#"{"worker": 1, "at": 0, "total_cpu": 0.5,
                "per_image": [{"image": "img", "cpu": 0.25, "ram": "oops"}], "pes": []}"#,
        )
        .unwrap();
        assert!(WorkerReport::from_json(&j).is_none());
    }
}
