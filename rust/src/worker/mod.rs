//! Worker node: hosts PE containers, runs the contention model, measures
//! per-PE CPU and sends periodic reports to the master (the worker half of
//! the paper's worker profiler).
//!
//! The contention model is processor sharing: busy PEs demand their
//! configured CPU fraction; if total demand exceeds the VM's capacity every
//! PE is throttled proportionally, stretching its service time — exactly
//! the effect that makes over-packing a worker slow (and that bin-packing
//! avoids by keeping scheduled load ≤ 1.0).

pub mod agent;
pub mod live;
pub mod pe;

use crate::binpacking::{Resource, ResourceVec};
use crate::clock::Periodic;
use crate::protocol::{PeStatus, WorkerReport};
use crate::types::{CpuFraction, IdGen, ImageName, Millis, PeId, StreamMessage, VmId, WorkerId};
use crate::util::rng::Rng;

pub use live::{LiveJob, LivePe, LiveResult};
pub use pe::{PePhase, ProcessingEngine};

/// Per-worker configuration (parameters from [15] §4.3 / Table 1 that live
/// on the worker side).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Container start latency (docker pull + start).
    pub container_boot: Millis,
    /// Jitter on the start latency (±, uniform).
    pub container_boot_jitter: Millis,
    /// Idle self-termination timeout (`container_idle_timeout`; the
    /// microscopy experiment sets 1 s).
    pub container_idle_timeout: Millis,
    /// Graceful container stop latency (docker stop → exited).
    pub container_stop: Millis,
    /// First-ever hosting of an image on this deployment pulls it from the
    /// registry (Docker Hub); later starts hit the local cache. The paper's
    /// run-1 warm-up penalty.
    pub image_pull: Millis,
    /// Report cadence to the master (`report_interval`; 1 s in §VI-B).
    pub report_interval: Millis,
    /// CPU fraction an idle PE consumes.
    pub idle_cpu: CpuFraction,
    /// Std-dev of OS measurement noise on total CPU (0 disables).
    pub measure_noise_std: f64,
    /// Relative std-dev of measurement noise on the non-CPU dimensions of
    /// the per-image report (RAM/net; 0 disables). Only drawn for images
    /// whose PEs actually hold RAM/net, so CPU-only deployments keep a
    /// byte-identical rng stream.
    pub resource_noise_std: f64,
    /// VM cores (capacity is normalized to 1.0 = all cores).
    pub cores: u32,
    /// Checkpoint cadence for busy PEs: every period the worker snapshots
    /// each busy PE's live progress fraction into its
    /// [`checkpoint`](ProcessingEngine::checkpoint), and the periodic
    /// report surfaces the per-image snapshots so the master can carry
    /// them into preemption re-hosting requests (work since the last
    /// snapshot is lost on preemption; work up to it is not redone).
    /// `Millis(0)` disables checkpointing entirely — the default, which
    /// keeps legacy runs byte-identical: no snapshots, no `progress`
    /// entries in reports, no extra rng draws.
    pub checkpoint_period: Millis,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            container_boot: Millis::from_secs(3),
            container_boot_jitter: Millis(1500),
            container_idle_timeout: Millis::from_secs(1),
            container_stop: Millis(2500),
            image_pull: Millis::from_secs(30),
            report_interval: Millis::from_secs(1),
            idle_cpu: CpuFraction::new(0.004),
            measure_noise_std: 0.01,
            resource_noise_std: 0.02,
            cores: 8,
            checkpoint_period: Millis::ZERO,
        }
    }
}

/// Events a worker surfaces to the coordination layer each tick.
#[derive(Clone, Debug)]
pub enum WorkerEvent {
    PeReady(PeId),
    JobCompleted {
        pe: PeId,
        msg: StreamMessage,
        completed_at: Millis,
    },
    /// Idle self-termination ("graceful").
    PeTerminated(PeId),
    Report(WorkerReport),
}

/// A worker node bound to a cloud VM.
pub struct Worker {
    pub id: WorkerId,
    pub vm: VmId,
    cfg: WorkerConfig,
    pes: Vec<ProcessingEngine>,
    pe_ids: IdGen,
    rng: Rng,
    report_timer: Periodic,
    /// Snapshot timer for the checkpointer; `None` when
    /// `checkpoint_period` is zero (checkpointing disabled).
    checkpoint_timer: Option<Periodic>,
    last_tick: Option<Millis>,
    /// Integrated (cpu·ms, busy·ms) per PE since the last report. Demand
    /// estimates average over *busy time only* so partially-busy intervals
    /// do not drag the profile below the true busy demand (which would
    /// make the bin-packing manager over-pack workers).
    acc_cpu_ms: Vec<(PeId, f64, f64)>,
    acc_window_ms: f64,
    /// Most recent instantaneous total CPU (with noise), for plots.
    pub last_total_cpu: CpuFraction,
}

impl Worker {
    pub fn new(id: WorkerId, vm: VmId, cfg: WorkerConfig, seed: u64) -> Self {
        let report_interval = cfg.report_interval;
        let checkpoint_timer = if cfg.checkpoint_period.0 > 0 {
            Some(Periodic::new(cfg.checkpoint_period))
        } else {
            None
        };
        Worker {
            id,
            vm,
            cfg,
            pes: Vec::new(),
            pe_ids: IdGen::new(),
            rng: Rng::seeded(seed),
            report_timer: Periodic::new(report_interval),
            checkpoint_timer,
            last_tick: None,
            acc_cpu_ms: Vec::new(),
            acc_window_ms: 0.0,
            last_total_cpu: CpuFraction::ZERO,
        }
    }

    pub fn config(&self) -> &WorkerConfig {
        &self.cfg
    }

    /// Start a new PE container for `image` with the given busy demand.
    /// `extra_boot` models a registry pull on the first hosting of the
    /// image (the caller owns the pull cache).
    pub fn start_pe_with_pull(
        &mut self,
        image: ImageName,
        busy_demand: CpuFraction,
        now: Millis,
        extra_boot: Millis,
    ) -> PeId {
        self.start_pe_full(image, busy_demand, ResourceVec::ZERO, now, extra_boot)
    }

    /// Start a new PE with a full resource footprint: `busy_demand` is
    /// CPU normalized to this worker; `busy_aux` is the RAM/net the PE
    /// holds while busy, in reference-VM units (what the periodic report
    /// carries so the master can profile the full vector live).
    pub fn start_pe_full(
        &mut self,
        image: ImageName,
        busy_demand: CpuFraction,
        busy_aux: ResourceVec,
        now: Millis,
        extra_boot: Millis,
    ) -> PeId {
        let jitter = if self.cfg.container_boot_jitter.0 == 0 {
            Millis::ZERO
        } else {
            // pallas-lint: allow(D3, condition is the static container_boot_jitter config — every PE start in a run takes the same arm, so the draw count per start is constant)
            Millis(self.rng.range(0, 2 * self.cfg.container_boot_jitter.0))
        };
        let boot = self
            .cfg
            .container_boot
            .saturating_sub(self.cfg.container_boot_jitter)
            + jitter
            + extra_boot;
        let id = PeId(self.pe_ids.next_id() | (self.id.0 << 32));
        self.pes.push(ProcessingEngine::with_aux(
            id,
            image,
            busy_demand,
            busy_aux,
            self.cfg.idle_cpu,
            now,
            boot,
        ));
        id
    }

    /// Start a PE with a warm image cache (no pull).
    pub fn start_pe(&mut self, image: ImageName, busy_demand: CpuFraction, now: Millis) -> PeId {
        self.start_pe_with_pull(image, busy_demand, now, Millis::ZERO)
    }

    /// Gracefully stop a PE (used by explicit scale-down). The container
    /// enters its stop phase and is removed once the stop latency elapses.
    pub fn stop_pe(&mut self, pe: PeId) -> bool {
        let stop = self.cfg.container_stop;
        if let Some(p) = self.pes.iter_mut().find(|p| p.id == pe) {
            p.phase = PePhase::Stopping {
                until: self.last_tick.unwrap_or(Millis::ZERO) + stop,
            };
            true
        } else {
            false
        }
    }

    /// Deliver a message P2P to a PE. On failure the message is returned so
    /// the caller can requeue it on the master backlog.
    pub fn deliver(&mut self, pe: PeId, msg: StreamMessage, now: Millis) -> Result<(), StreamMessage> {
        match self.pes.iter_mut().find(|p| p.id == pe) {
            Some(p) => p.deliver(msg, now),
            None => Err(msg),
        }
    }

    /// First idle PE hosting `image`, if any (the master's routing query).
    pub fn find_idle_pe(&self, image: &ImageName) -> Option<PeId> {
        self.pes
            .iter()
            .find(|p| p.is_idle() && &p.image == image)
            .map(|p| p.id)
    }

    pub fn pes(&self) -> &[ProcessingEngine] {
        &self.pes
    }

    /// `(image, last checkpoint)` for every hosted PE — what a preemption
    /// notice hands the IRM so each re-hosting request carries the
    /// progress snapshot of the PE it replaces. Uncheckpointed, idle and
    /// booting PEs report `0.0` (their replacement starts from scratch).
    pub fn hosted_with_checkpoints(&self) -> Vec<(ImageName, f64)> {
        self.pes
            .iter()
            .map(|p| (p.image.clone(), p.checkpoint))
            .collect()
    }

    pub fn pe_count(&self) -> usize {
        self.pes.len()
    }

    pub fn pe_count_for(&self, image: &ImageName) -> usize {
        self.pes.iter().filter(|p| &p.image == image).count()
    }

    /// Sum of busy demands + idle overheads — the "scheduled" load proxy.
    pub fn demand_total(&self) -> CpuFraction {
        self.pes
            .iter()
            .fold(CpuFraction::ZERO, |acc, p| acc + p.demand())
    }

    /// Time of the last `tick` observation (`None` before the first tick).
    pub fn last_tick(&self) -> Option<Millis> {
        self.last_tick
    }

    /// Earliest future time at which ticking this worker can change its
    /// state or emit an event — the wheel deadline under which skipping
    /// intermediate ticks is provably equivalent to taking them (see
    /// `rust/src/sim/README.md` for the full argument). Two cases pin the
    /// worker to every tick: a busy PE (per-tick progress applies
    /// `round(dt·factor).max(1 ms)`, which is nonlinear in `dt`) and
    /// per-tick measurement noise (one rng draw per observation, so the
    /// stream length depends on the tick count). Everything else — boot
    /// completions, idle timeouts, stop latencies, the report cadence — is
    /// a pure deadline. The report timer always supplies one, so an idle
    /// worker is observed at least once per report interval.
    pub fn next_due(&self, now: Millis) -> Millis {
        let every_tick = now + Millis(1);
        if self.cfg.measure_noise_std > 0.0
            || self
                .pes
                .iter()
                .any(|p| matches!(p.phase, PePhase::Busy { .. }))
        {
            return every_tick;
        }
        let mut due = match self.report_timer.next_fire() {
            Some(t) => t,
            // Never ticked: anything due immediately.
            None => return every_tick,
        };
        let timeout = self.cfg.container_idle_timeout;
        for p in &self.pes {
            let t = match p.phase {
                PePhase::Booting { ready_at } => ready_at,
                PePhase::Idle { since } if timeout.0 > 0 => since + timeout,
                PePhase::Stopping { until } => until,
                _ => continue,
            };
            due = due.min(t);
        }
        due.max(every_tick)
    }

    /// Advance the worker by one step ending at `now`.
    pub fn tick(&mut self, now: Millis) -> Vec<WorkerEvent> {
        let mut events = Vec::new();
        self.tick_into(now, &mut events);
        events
    }

    /// Advance the worker, appending events to a caller-owned buffer — the
    /// simulator's per-tick path, so a loaded cluster doesn't allocate one
    /// event vector per worker per tick.
    pub fn tick_into(&mut self, now: Millis, events: &mut Vec<WorkerEvent>) {
        let dt = match self.last_tick {
            None => Millis::ZERO,
            Some(last) => now - last,
        };
        self.last_tick = Some(now);

        // 1. Boot transitions.
        for p in &mut self.pes {
            if let PePhase::Booting { ready_at } = p.phase {
                if now >= ready_at {
                    p.phase = PePhase::Idle { since: now };
                    events.push(WorkerEvent::PeReady(p.id));
                }
            }
        }

        // 2. Contention model: grant CPU, advance busy jobs.
        let total_demand: f64 = self.pes.iter().map(|p| p.demand().value()).sum();
        let factor = if total_demand > 1.0 {
            1.0 / total_demand
        } else {
            1.0
        };
        let mut measured_total = 0.0;
        for p in &mut self.pes {
            let granted = p.demand().value() * factor;
            p.granted = CpuFraction::new(granted);
            measured_total += granted;
            if dt.0 > 0 {
                if let PePhase::Busy {
                    ref mut remaining, ..
                } = p.phase
                {
                    // Service progresses at the throttle factor.
                    let progress =
                        Millis(crate::util::cast::f64_to_u64(((dt.0 as f64) * factor).round()));
                    *remaining = remaining.saturating_sub(progress.max(Millis(1)));
                }
            }
            // Accumulate (cpu·ms, busy·ms) for the report-interval average.
            if dt.0 > 0 && matches!(p.phase, PePhase::Busy { .. }) {
                match self.acc_cpu_ms.iter_mut().find(|(id, _, _)| *id == p.id) {
                    Some((_, cpu, busy)) => {
                        *cpu += granted * dt.0 as f64;
                        *busy += dt.0 as f64;
                    }
                    None => self
                        .acc_cpu_ms
                        .push((p.id, granted * dt.0 as f64, dt.0 as f64)),
                }
            }
        }
        self.acc_window_ms += dt.0 as f64;

        // 3. Completions.
        for p in &mut self.pes {
            if let PePhase::Busy { remaining, .. } = &p.phase {
                if remaining.0 == 0 {
                    if let PePhase::Busy { msg, .. } =
                        std::mem::replace(&mut p.phase, PePhase::Idle { since: now })
                    {
                        p.jobs_done += 1;
                        p.checkpoint = 0.0;
                        events.push(WorkerEvent::JobCompleted {
                            pe: p.id,
                            msg,
                            completed_at: now,
                        });
                    }
                }
            }
        }

        // 3b. Checkpointer: snapshot every busy PE's live progress on the
        // configured cadence. Runs after completions so a message that
        // just finished is never snapshotted.
        if let Some(timer) = &mut self.checkpoint_timer {
            if timer.fire(now) {
                for p in &mut self.pes {
                    if matches!(p.phase, PePhase::Busy { .. }) {
                        p.checkpoint = p.progress();
                    }
                }
            }
        }

        // 4. Idle self-termination: idle → graceful stop → terminated.
        let timeout = self.cfg.container_idle_timeout;
        let stop = self.cfg.container_stop;
        for p in &mut self.pes {
            match p.phase {
                PePhase::Idle { since } => {
                    if now >= since + timeout && timeout.0 > 0 {
                        p.phase = PePhase::Stopping { until: now + stop };
                    }
                }
                PePhase::Stopping { until } => {
                    if now >= until {
                        p.phase = PePhase::Terminated;
                        events.push(WorkerEvent::PeTerminated(p.id));
                    }
                }
                _ => {}
            }
        }
        self.pes.retain(|p| p.phase != PePhase::Terminated);

        // 5. Measurement noise (only on the externally observed total).
        let noise = if self.cfg.measure_noise_std > 0.0 {
            // pallas-lint: allow(D3, condition is the static measure_noise_std config — every tick in a run takes the same arm, so noise-free runs keep a byte-identical stream by construction)
            self.rng.normal_with(0.0, self.cfg.measure_noise_std)
        } else {
            0.0
        };
        self.last_total_cpu = CpuFraction::new((measured_total + noise).max(0.0));

        // 6. Periodic report.
        if self.report_timer.fire(now) {
            events.push(WorkerEvent::Report(self.report(now)));
            self.acc_cpu_ms.clear();
            self.acc_window_ms = 0.0;
        }
    }

    /// Build the report from busy-time-averaged CPU per PE.
    fn report(&mut self, now: Millis) -> WorkerReport {
        // Worker-side busy heuristic: a PE whose interval-averaged CPU
        // sits below this is treated as idle for the per-image pool (the
        // master-side profiler has its own, configurable floors).
        const BUSY_CPU: f64 = 0.02;
        let avg_for = |id: PeId, fallback: f64| -> f64 {
            self.acc_cpu_ms
                .iter()
                .find(|(pid, _, _)| *pid == id)
                .map(|(_, cpu, busy)| cpu / busy.max(1.0))
                .unwrap_or(fallback)
        };
        let pes: Vec<PeStatus> = self
            .pes
            .iter()
            .map(|p| PeStatus {
                pe: p.id,
                image: p.image.clone(),
                state: p.state(),
                cpu: CpuFraction::new(avg_for(p.id, p.granted.value())),
            })
            .collect();

        // Per-image average over that image's PEs (the paper's §V-B3,
        // extended to the full resource vector). The busy-demand estimate
        // only makes sense over PEs that actually worked in the interval;
        // all-idle intervals report the raw mean (which the master-side
        // profiler filters below its per-dimension busy floors).
        let mut images: Vec<ImageName> = self.pes.iter().map(|p| p.image.clone()).collect();
        images.sort();
        images.dedup();
        let mut per_image = Vec::with_capacity(images.len());
        for img in images {
            let vals: Vec<(f64, ResourceVec)> = self
                .pes
                .iter()
                .filter(|p| p.image == img)
                .map(|p| {
                    // Busy-time-averaged footprint, mirroring the CPU
                    // average: a PE that worked this interval held its
                    // busy footprint while doing so — sampling the phase
                    // it happens to be in at the report instant would
                    // dilute the estimate whenever a job completes just
                    // before the report fires. Only busy-pool entries
                    // are ever read below, so the footprint is always
                    // `busy_aux`.
                    (avg_for(p.id, p.granted.value()), p.busy_aux)
                })
                .collect();
            let busy: Vec<(f64, ResourceVec)> =
                vals.iter().copied().filter(|(v, _)| *v > BUSY_CPU).collect();
            let pool = if busy.is_empty() { &vals } else { &busy };
            let n = pool.len().max(1) as f64;
            let cpu = pool.iter().map(|(v, _)| *v).sum::<f64>() / n;
            // RAM/net come from the busy pool only: the all-idle fallback
            // exists so the CPU series stays observable, but an idle
            // interval has no busy *footprint* to report — averaging the
            // idle/stopping leftovers in would emit diluted samples that
            // pass the master's RAM floor and drag the busy estimate
            // below truth.
            let nb = busy.len().max(1) as f64;
            let mut ram = busy.iter().map(|(_, a)| a.get(Resource::Ram)).sum::<f64>() / nb;
            let mut net = busy.iter().map(|(_, a)| a.get(Resource::Net)).sum::<f64>() / nb;
            // Measurement noise on the non-CPU dimensions — drawn only
            // when there is something to measure, so CPU-only runs keep
            // a byte-identical rng stream.
            if self.cfg.resource_noise_std > 0.0 {
                if ram > 0.0 {
                    // pallas-lint: allow(D3, deliberate stream conditioning — drawing only when a footprint exists keeps CPU-only runs byte-identical to pre-multidim trajectories (see the comment above); the goldens pin both regimes)
                    let f = 1.0 + self.rng.normal_with(0.0, self.cfg.resource_noise_std);
                    ram = (ram * f).max(0.0);
                }
                if net > 0.0 {
                    // pallas-lint: allow(D3, deliberate stream conditioning — same argument as the ram draw above; the multidim golden pins this trajectory)
                    let f = 1.0 + self.rng.normal_with(0.0, self.cfg.resource_noise_std);
                    net = (net * f).max(0.0);
                }
            }
            per_image.push((img, ResourceVec::new(cpu, ram, net)));
        }

        // Per-image checkpoint progress: the furthest snapshot among the
        // image's PEs. Only emitted when the checkpointer is enabled, so
        // legacy (checkpoint-free) reports stay byte-identical on the
        // wire.
        let progress: Vec<(ImageName, f64)> = if self.checkpoint_timer.is_some() {
            per_image
                .iter()
                .map(|(img, _)| {
                    let best = self
                        .pes
                        .iter()
                        .filter(|p| &p.image == img)
                        .map(|p| p.checkpoint)
                        .fold(0.0f64, f64::max);
                    (img.clone(), best)
                })
                .collect()
        } else {
            Vec::new()
        };

        WorkerReport {
            worker: self.id,
            at: now,
            total_cpu: self.last_total_cpu,
            per_image,
            progress,
            pes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::MessageId;

    fn quiet_cfg() -> WorkerConfig {
        WorkerConfig {
            container_boot: Millis(2000),
            container_boot_jitter: Millis::ZERO,
            container_idle_timeout: Millis::from_secs(3600), // effectively off
            container_stop: Millis(500),
            image_pull: Millis::ZERO,
            report_interval: Millis::from_secs(1),
            idle_cpu: CpuFraction::new(0.0),
            measure_noise_std: 0.0,
            resource_noise_std: 0.0,
            cores: 8,
            checkpoint_period: Millis::ZERO,
        }
    }

    fn msg(id: u64, demand_ms: u64) -> StreamMessage {
        StreamMessage {
            id: MessageId(id),
            image: ImageName::new("img"),
            payload_bytes: 1 << 20,
            service_demand: Millis(demand_ms),
            created_at: Millis(0),
        }
    }

    fn run_until(w: &mut Worker, from: Millis, to: Millis, dt: Millis) -> Vec<WorkerEvent> {
        let mut all = Vec::new();
        let mut t = from;
        while t <= to {
            all.extend(w.tick(t));
            t += dt;
        }
        all
    }

    #[test]
    fn pe_boots_and_becomes_routable() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        w.start_pe(img.clone(), CpuFraction::new(0.125), Millis(0));
        assert_eq!(w.find_idle_pe(&img), None);
        let events = run_until(&mut w, Millis(0), Millis(2500), Millis(100));
        assert!(events
            .iter()
            .any(|e| matches!(e, WorkerEvent::PeReady(_))));
        assert!(w.find_idle_pe(&img).is_some());
    }

    #[test]
    fn job_runs_for_service_time_uncontended() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.125), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 5000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(10_000), Millis(100));
        let done_at = events
            .iter()
            .find_map(|e| match e {
                WorkerEvent::JobCompleted { completed_at, .. } => Some(*completed_at),
                _ => None,
            })
            .expect("job completed");
        // ~5000ms of service starting at 2000ms -> completes ≈7000ms.
        assert!(done_at >= Millis(6900) && done_at <= Millis(7300), "{done_at:?}");
    }

    #[test]
    fn contention_stretches_service_time() {
        // Two PEs each demanding 0.8 on one VM -> total 1.6, throttle 0.625:
        // a 4 s job takes ≈6.4 s.
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let a = w.start_pe(img.clone(), CpuFraction::new(0.8), Millis(0));
        let b = w.start_pe(img.clone(), CpuFraction::new(0.8), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(a, msg(1, 4000), Millis(2000)).unwrap();
        w.deliver(b, msg(2, 4000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(12_000), Millis(100));
        let done: Vec<Millis> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::JobCompleted { completed_at, .. } => Some(*completed_at),
                _ => None,
            })
            .collect();
        assert_eq!(done.len(), 2);
        for d in done {
            assert!(d >= Millis(8200) && d <= Millis(8800), "{d:?}");
        }
    }

    #[test]
    fn measured_cpu_tracks_demand() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.5), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 60_000), Millis(2000)).unwrap();
        w.tick(Millis(2100));
        assert!((w.last_total_cpu.value() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn idle_timeout_terminates_pe() {
        let mut cfg = quiet_cfg();
        cfg.container_idle_timeout = Millis(1000);
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 1);
        w.start_pe(ImageName::new("img"), CpuFraction::new(0.1), Millis(0));
        let events = run_until(&mut w, Millis(0), Millis(4000), Millis(100));
        assert!(events
            .iter()
            .any(|e| matches!(e, WorkerEvent::PeTerminated(_))));
        assert_eq!(w.pe_count(), 0);
    }

    #[test]
    fn deliver_to_busy_pe_returns_message() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.125), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 10_000), Millis(2000)).unwrap();
        let back = w.deliver(pe, msg(2, 10_000), Millis(2000));
        assert!(back.is_err());
        assert_eq!(back.unwrap_err().id, MessageId(2));
    }

    #[test]
    fn reports_on_interval_with_per_image_avg() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.25), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 30_000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(4000), Millis(100));
        let reports: Vec<&WorkerReport> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Report(r) => Some(r),
                _ => None,
            })
            .collect();
        assert!(reports.len() >= 2);
        let last = reports.last().unwrap();
        let (rimg, usage) = &last.per_image[0];
        assert_eq!(rimg, &img);
        let cpu = usage.get(Resource::Cpu);
        assert!((cpu - 0.25).abs() < 0.02, "avg {cpu}");
        // A CPU-only PE reports zero RAM/net.
        assert_eq!(usage.get(Resource::Ram), 0.0);
        assert_eq!(usage.get(Resource::Net), 0.0);
    }

    #[test]
    fn busy_pe_reports_its_resource_vector() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe_full(
            img.clone(),
            CpuFraction::new(0.25),
            ResourceVec::new(0.0, 0.3, 0.05),
            Millis(0),
            Millis::ZERO,
        );
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 30_000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(4000), Millis(100));
        let last = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Report(r) => Some(r),
                _ => None,
            })
            .last()
            .expect("reported");
        let (_, usage) = &last.per_image[0];
        // Noise disabled in quiet_cfg: the busy footprint comes back
        // exactly.
        assert!((usage.get(Resource::Ram) - 0.3).abs() < 1e-9);
        assert!((usage.get(Resource::Net) - 0.05).abs() < 1e-9);
        assert!(usage.get(Resource::Cpu) > 0.2);
    }

    #[test]
    fn resource_noise_jitters_but_stays_nonnegative() {
        let mut cfg = quiet_cfg();
        cfg.resource_noise_std = 0.1;
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 9);
        let img = ImageName::new("img");
        let pe = w.start_pe_full(
            img.clone(),
            CpuFraction::new(0.25),
            ResourceVec::new(0.0, 0.3, 0.05),
            Millis(0),
            Millis::ZERO,
        );
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 60_000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(8000), Millis(100));
        let rams: Vec<f64> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Report(r) => r.per_image.first().map(|(_, u)| u.get(Resource::Ram)),
                _ => None,
            })
            .collect();
        assert!(rams.len() >= 3);
        assert!(rams.iter().all(|r| *r >= 0.0));
        // Samples scatter around the truth instead of repeating it.
        assert!(rams.iter().any(|r| (r - 0.3).abs() > 1e-6), "{rams:?}");
        let mean = rams.iter().sum::<f64>() / rams.len() as f64;
        assert!((mean - 0.3).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn checkpointer_snapshots_busy_progress_and_reports_it() {
        let mut cfg = quiet_cfg();
        cfg.checkpoint_period = Millis(1000);
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.25), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 10_000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(7000), Millis(100));
        // ~5 s into a 10 s job, the last snapshot sits near the live
        // progress and strictly behind it (snapshots lag by up to a
        // period — the work at risk on preemption).
        let hosted = w.hosted_with_checkpoints();
        assert_eq!(hosted.len(), 1);
        let (himg, ckpt) = &hosted[0];
        assert_eq!(himg, &img);
        assert!(*ckpt > 0.2 && *ckpt <= 0.5, "checkpoint {ckpt}");
        assert!(*ckpt <= w.pes()[0].progress() + 1e-12);
        // The periodic report surfaces the snapshot per image.
        let last = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Report(r) => Some(r),
                _ => None,
            })
            .last()
            .expect("reported");
        assert_eq!(last.progress.len(), 1);
        assert_eq!(last.progress[0].0, img);
        assert!(last.progress[0].1 > 0.0);
    }

    #[test]
    fn disabled_checkpointer_reports_no_progress_entries() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.25), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        w.deliver(pe, msg(1, 10_000), Millis(2000)).unwrap();
        let events = run_until(&mut w, Millis(2100), Millis(5000), Millis(100));
        let last = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Report(r) => Some(r),
                _ => None,
            })
            .last()
            .expect("reported");
        assert!(last.progress.is_empty(), "legacy reports carry no progress");
        assert_eq!(w.hosted_with_checkpoints()[0].1, 0.0);
    }

    #[test]
    fn stopping_pe_burns_cleanup_cpu_but_is_unroutable() {
        let mut cfg = quiet_cfg();
        cfg.container_idle_timeout = Millis(500);
        cfg.container_stop = Millis(2000);
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 1);
        let img = ImageName::new("img");
        w.start_pe(img.clone(), CpuFraction::new(0.4), Millis(0));
        run_until(&mut w, Millis(0), Millis(2000), Millis(100));
        assert!(w.find_idle_pe(&img).is_some());
        // Idle past the timeout → Stopping: no longer routable, but the
        // cleanup CPU (half busy demand) is still measured.
        run_until(&mut w, Millis(2100), Millis(2700), Millis(100));
        assert!(w.find_idle_pe(&img).is_none(), "stopping PE unroutable");
        assert_eq!(w.pe_count(), 1, "still winding down");
        assert!(
            (w.last_total_cpu.value() - 0.2).abs() < 1e-9,
            "cleanup cpu measured: {}",
            w.last_total_cpu.value()
        );
        // After the stop latency it is gone.
        run_until(&mut w, Millis(2800), Millis(5200), Millis(100));
        assert_eq!(w.pe_count(), 0);
    }

    #[test]
    fn image_pull_delays_first_boot() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let img = ImageName::new("img");
        // Cold start: 2 s boot + 10 s pull.
        w.start_pe_with_pull(img.clone(), CpuFraction::new(0.1), Millis(0), Millis(10_000));
        let events = run_until(&mut w, Millis(0), Millis(11_000), Millis(100));
        let ready_at = events.iter().find_map(|e| match e {
            WorkerEvent::PeReady(_) => Some(()),
            _ => None,
        });
        assert!(ready_at.is_none() || w.pes()[0].state() != crate::protocol::PeState::Booting);
        // It must not have been ready before ~12 s.
        let early: Vec<&WorkerEvent> = events
            .iter()
            .filter(|e| matches!(e, WorkerEvent::PeReady(_)))
            .collect();
        assert!(early.is_empty(), "pull must delay readiness past 11 s");
        let events = run_until(&mut w, Millis(11_100), Millis(13_000), Millis(100));
        assert!(events
            .iter()
            .any(|e| matches!(e, WorkerEvent::PeReady(_))));
    }

    #[test]
    fn pe_ids_unique_across_workers() {
        let mut w0 = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let mut w1 = Worker::new(WorkerId(1), VmId(1), quiet_cfg(), 2);
        let a = w0.start_pe(ImageName::new("img"), CpuFraction::new(0.1), Millis(0));
        let b = w1.start_pe(ImageName::new("img"), CpuFraction::new(0.1), Millis(0));
        assert_ne!(a, b);
    }

    #[test]
    fn next_due_is_the_earliest_state_change() {
        let mut cfg = quiet_cfg();
        cfg.container_idle_timeout = Millis(1000);
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 1);
        let img = ImageName::new("img");
        let pe = w.start_pe(img.clone(), CpuFraction::new(0.25), Millis(0));
        w.tick(Millis(0));
        // Booting PE (ready at 2000) beats the report timer (due 1000)?
        // No — the report at 1000 is earlier; it wins.
        assert_eq!(w.next_due(Millis(0)), Millis(1000));
        run_until(&mut w, Millis(100), Millis(1900), Millis(100));
        // Next report due 2000, boot also due 2000.
        assert_eq!(w.next_due(Millis(1900)), Millis(2000));
        run_until(&mut w, Millis(2000), Millis(2000), Millis(100));
        // Now idle since 2000: idle timeout at 3000 == report at 3000.
        assert_eq!(w.next_due(Millis(2000)), Millis(3000));
        // A busy PE pins the worker to every tick.
        run_until(&mut w, Millis(2100), Millis(2500), Millis(100));
        w.deliver(pe, msg(1, 5000), Millis(2500)).unwrap();
        assert_eq!(w.next_due(Millis(2500)), Millis(2501));
    }

    #[test]
    fn next_due_with_measurement_noise_is_every_tick() {
        let mut cfg = quiet_cfg();
        cfg.measure_noise_std = 0.01;
        let mut w = Worker::new(WorkerId(0), VmId(0), cfg, 1);
        w.tick(Millis(0));
        assert_eq!(w.next_due(Millis(0)), Millis(1));
    }

    #[test]
    fn skipping_to_next_due_matches_per_tick_state() {
        // The wheel's core contract: for a worker with no busy PEs and no
        // noise, one catch-up tick at the due time leaves byte-identical
        // state and events versus ticking every dt.
        let mut cfg = quiet_cfg();
        cfg.container_idle_timeout = Millis(1000);
        let mk = || {
            let mut w = Worker::new(WorkerId(0), VmId(0), cfg.clone(), 7);
            w.start_pe(ImageName::new("img"), CpuFraction::new(0.25), Millis(0));
            w.tick(Millis(0));
            w
        };
        let mut dense = mk();
        let mut sparse = mk();
        let mut dense_events = Vec::new();
        let mut t = Millis(100);
        while t <= Millis(6000) {
            dense.tick_into(t, &mut dense_events);
            t += Millis(100);
        }
        let mut sparse_events = Vec::new();
        let mut now = Millis(0);
        while now < Millis(6000) {
            let due = sparse.next_due(now);
            // Land on the tick grid like the cluster does: first grid
            // point at or after the deadline.
            let at = Millis((due.0 + 99) / 100 * 100).min(Millis(6000));
            sparse.tick_into(at, &mut sparse_events);
            now = at;
        }
        assert_eq!(format!("{dense_events:?}"), format!("{sparse_events:?}"));
        assert_eq!(dense.pe_count(), sparse.pe_count());
        assert_eq!(dense.last_total_cpu.value(), sparse.last_total_cpu.value());
    }

    #[test]
    fn stop_pe_removes_after_tick() {
        let mut w = Worker::new(WorkerId(0), VmId(0), quiet_cfg(), 1);
        let pe = w.start_pe(ImageName::new("img"), CpuFraction::new(0.1), Millis(0));
        w.tick(Millis(0));
        assert!(w.stop_pe(pe));
        // Graceful stop: the container winds down for container_stop
        // (500 ms in quiet_cfg) before it disappears.
        w.tick(Millis(100));
        assert_eq!(w.pe_count(), 1, "still stopping");
        w.tick(Millis(700));
        assert_eq!(w.pe_count(), 0);
        assert!(!w.stop_pe(pe), "already gone");
    }
}
