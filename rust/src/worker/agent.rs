//! Remote worker agent: a worker node as its own process/endpoint.
//!
//! The distributed deployment of Fig 1: the master only tracks worker
//! state; *data* travels P2P from the stream connector straight to a
//! worker's endpoint ("messages are forwarded directly to available PEs
//! for processing"). The agent wraps a live PE pool behind a TCP server
//! with three endpoints:
//!
//! * `analyze {pixels}` — accept one message P2P, process, reply with the
//!   features (rejects with `busy` when no PE can take it, so the caller
//!   falls back to the master backlog);
//! * `status {}` — idle/total PEs + mailbox depth (the worker report the
//!   master's registry consumes);
//! * `ping {}` — liveness.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::master::{LiveCluster, LiveConfig};
use crate::transport::{Handler, Server};
use crate::util::json::Json;

/// A running worker agent (server + shared PE pool).
pub struct WorkerAgent {
    pub server: Server,
    cluster: Arc<Mutex<LiveCluster>>,
}

impl WorkerAgent {
    /// Start an agent over the given artifacts with `pes` live PEs.
    pub fn start(addr: &str, artifacts_dir: &str, pes: usize) -> Result<WorkerAgent> {
        let cluster = LiveCluster::new(
            artifacts_dir,
            LiveConfig {
                max_pes: pes,
                initial_pes: pes,
                scale_up_backlog_per_pe: usize::MAX, // fixed pool: master scales
            },
        )?;
        let cluster = Arc::new(Mutex::new(cluster));
        let handler_cluster = cluster.clone();
        let handler: Handler = Arc::new(move |req: Json| {
            let kind = req.get("type").and_then(|t| t.as_str()).unwrap_or("");
            match kind {
                "ping" => Json::obj([("ok", Json::Bool(true))]),
                "status" => {
                    let mut c = handler_cluster.lock().unwrap();
                    c.pump();
                    Json::obj([
                        ("ok", Json::Bool(true)),
                        ("pes", Json::num(c.pe_count() as f64)),
                        ("completed", Json::num(c.stats.completed as f64)),
                        ("submitted", Json::num(c.stats.submitted as f64)),
                        (
                            "busy",
                            Json::num((c.stats.submitted - c.stats.completed) as f64),
                        ),
                    ])
                }
                "analyze" => {
                    let Some(pixels) = decode_pixels(&req) else {
                        return Json::obj([
                            ("ok", Json::Bool(false)),
                            ("error", Json::str("missing pixels")),
                        ]);
                    };
                    // P2P admission control: only accept when a PE can take
                    // the message now; otherwise the connector must fall
                    // back to the master backlog.
                    let id = {
                        let mut c = handler_cluster.lock().unwrap();
                        let in_flight = c.stats.submitted - c.stats.completed;
                        if in_flight >= 2 * c.pe_count() as u64 {
                            return Json::obj([
                                ("ok", Json::Bool(false)),
                                ("error", Json::str("busy")),
                            ]);
                        }
                        c.stream(pixels)
                    };
                    let t0 = std::time::Instant::now();
                    loop {
                        {
                            let mut c = handler_cluster.lock().unwrap();
                            c.pump();
                            if let Some(r) = c.results.iter().find(|r| r.id == id) {
                                return Json::obj([
                                    ("ok", Json::Bool(true)),
                                    (
                                        "features",
                                        Json::arr(
                                            r.features.iter().map(|f| Json::num(*f as f64)),
                                        ),
                                    ),
                                    (
                                        "wall_ms",
                                        Json::num(r.wall.as_secs_f64() * 1e3),
                                    ),
                                ]);
                            }
                        }
                        if t0.elapsed() > std::time::Duration::from_secs(120) {
                            return Json::obj([
                                ("ok", Json::Bool(false)),
                                ("error", Json::str("timeout")),
                            ]);
                        }
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                }
                other => Json::obj([
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(format!("unknown request '{other}'"))),
                ]),
            }
        });
        let server = Server::start(addr, handler)?;
        Ok(WorkerAgent { server, cluster })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }

    pub fn completed(&self) -> u64 {
        self.cluster.lock().unwrap().stats.completed
    }

    pub fn shutdown(self) {
        self.server.shutdown();
    }
}

/// Shared pixel decoding for agent/master services.
pub fn decode_pixels(req: &Json) -> Option<Vec<f32>> {
    req.get("pixels")?.as_arr().map(|a| {
        a.iter()
            .filter_map(|v| v.as_f64().map(|f| f as f32))
            .collect()
    })
}
