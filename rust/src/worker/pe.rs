//! Processing Engine (PE): the containerized unit of processing.
//!
//! A PE hosts the user's analysis container (here: the AOT-compiled nuclei
//! pipeline or the synthetic busy kernel). Lifecycle mirrors Docker
//! containers in the paper: a start latency (pull/boot), an idle state
//! accepting at most one message at a time, and graceful self-termination
//! after a configurable idle timeout ("After a time of being idle, a PE
//! will self-terminate gracefully in order to free the resources").

use crate::binpacking::ResourceVec;
use crate::protocol::PeState;
use crate::types::{CpuFraction, ImageName, Millis, PeId, StreamMessage};

/// Internal PE lifecycle (richer than the reported [`PeState`]).
#[derive(Clone, Debug, PartialEq)]
pub enum PePhase {
    Booting {
        ready_at: Millis,
    },
    Idle {
        since: Millis,
    },
    Busy {
        msg: StreamMessage,
        /// Remaining service time at full CPU allocation.
        remaining: Millis,
        started_at: Millis,
    },
    /// Graceful self-termination in progress (docker stop latency).
    Stopping {
        until: Millis,
    },
    Terminated,
}

/// One processing engine.
#[derive(Clone, Debug)]
pub struct ProcessingEngine {
    pub id: PeId,
    pub image: ImageName,
    /// CPU fraction of the *whole VM* the PE demands while busy (a
    /// single-core container on an 8-core worker demands 0.125).
    pub busy_demand: CpuFraction,
    /// Non-CPU resources the PE holds while busy, in **reference-VM
    /// units** (the CPU component is unused — `busy_demand` owns it,
    /// normalized to this worker). RAM is the decompressed working set,
    /// net the streaming bandwidth; both are what the worker-side
    /// profiler measures and reports so the master can pack on live
    /// vectors instead of static guesses.
    pub busy_aux: ResourceVec,
    /// Background CPU while idle (container overhead).
    pub idle_cpu: CpuFraction,
    pub phase: PePhase,
    pub jobs_done: u64,
    /// CPU actually granted in the last tick (set by the worker's
    /// contention model; what the profiler measures).
    pub granted: CpuFraction,
    /// Last snapshotted progress fraction of the current busy message
    /// (0.0..=1.0), taken by the worker's periodic checkpointer. `0.0`
    /// when checkpointing is disabled, the PE is not busy, or no
    /// snapshot has fired yet. On preemption the re-hosting request
    /// carries this value so the replacement PE resumes from the
    /// snapshot instead of re-running the message from scratch.
    pub checkpoint: f64,
}

impl ProcessingEngine {
    pub fn new(
        id: PeId,
        image: ImageName,
        busy_demand: CpuFraction,
        idle_cpu: CpuFraction,
        now: Millis,
        boot_delay: Millis,
    ) -> Self {
        Self::with_aux(
            id,
            image,
            busy_demand,
            ResourceVec::ZERO,
            idle_cpu,
            now,
            boot_delay,
        )
    }

    /// A PE whose busy phase also holds the given RAM/net footprint (the
    /// heterogeneous/vector workloads; CPU-only callers use [`Self::new`]).
    #[allow(clippy::too_many_arguments)]
    pub fn with_aux(
        id: PeId,
        image: ImageName,
        busy_demand: CpuFraction,
        busy_aux: ResourceVec,
        idle_cpu: CpuFraction,
        now: Millis,
        boot_delay: Millis,
    ) -> Self {
        ProcessingEngine {
            id,
            image,
            busy_demand,
            busy_aux,
            idle_cpu,
            phase: PePhase::Booting {
                ready_at: now + boot_delay,
            },
            jobs_done: 0,
            granted: CpuFraction::ZERO,
            checkpoint: 0.0,
        }
    }

    /// Live progress fraction of the current busy message: work done so
    /// far over its total service demand, in `0.0..=1.0`. Zero when not
    /// busy. This is what the periodic checkpointer snapshots into
    /// [`checkpoint`](Self::checkpoint) — the live value itself is not
    /// recoverable after a preemption (state since the last snapshot is
    /// lost), which is exactly the gap the checkpoint period trades
    /// against overhead.
    pub fn progress(&self) -> f64 {
        match &self.phase {
            PePhase::Busy { msg, remaining, .. } => {
                let total = msg.service_demand.0;
                if total == 0 {
                    0.0
                } else {
                    (1.0 - remaining.0 as f64 / total as f64).clamp(0.0, 1.0)
                }
            }
            _ => 0.0,
        }
    }

    pub fn state(&self) -> PeState {
        match self.phase {
            PePhase::Booting { .. } => PeState::Booting,
            PePhase::Idle { .. } => PeState::Idle,
            PePhase::Busy { .. } => PeState::Busy,
            PePhase::Stopping { .. } => PeState::Stopping,
            PePhase::Terminated => PeState::Terminated,
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self.phase, PePhase::Idle { .. })
    }

    /// CPU demand in the current phase (input to the contention model).
    /// A stopping container still burns cleanup CPU (about half its busy
    /// demand) while it flushes and exits — the source of the paper's
    /// negative error dips when idle PEs terminate in bursts.
    pub fn demand(&self) -> CpuFraction {
        match self.phase {
            PePhase::Busy { .. } => self.busy_demand,
            PePhase::Idle { .. } => self.idle_cpu,
            PePhase::Stopping { .. } => CpuFraction::new(self.busy_demand.value() * 0.5),
            _ => CpuFraction::ZERO,
        }
    }

    /// Non-CPU resources held in the current phase, in reference-VM
    /// units — the *instantaneous* phase model, mirroring the CPU demand
    /// model: the full busy footprint while processing, half while a
    /// stopping container flushes, nothing while booting or idle. (The
    /// worker's periodic report averages over busy time instead —
    /// [`busy_aux`](Self::busy_aux) for PEs that worked in the interval —
    /// so a job completing just before the report fires cannot dilute
    /// the profiled estimate.)
    pub fn aux_usage(&self) -> ResourceVec {
        match self.phase {
            PePhase::Busy { .. } => self.busy_aux,
            PePhase::Stopping { .. } => {
                let mut half = self.busy_aux;
                for v in &mut half.0 {
                    *v *= 0.5;
                }
                half
            }
            _ => ResourceVec::ZERO,
        }
    }

    /// Accept a message (only valid when idle).
    pub fn deliver(&mut self, msg: StreamMessage, now: Millis) -> Result<(), StreamMessage> {
        if self.is_idle() {
            self.phase = PePhase::Busy {
                remaining: msg.service_demand,
                msg,
                started_at: now,
            };
            self.checkpoint = 0.0;
            Ok(())
        } else {
            Err(msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::MessageId;

    fn msg(demand_ms: u64) -> StreamMessage {
        StreamMessage {
            id: MessageId(0),
            image: ImageName::new("img"),
            payload_bytes: 1024,
            service_demand: Millis(demand_ms),
            created_at: Millis(0),
        }
    }

    fn pe(now: Millis) -> ProcessingEngine {
        ProcessingEngine::new(
            PeId(1),
            ImageName::new("img"),
            CpuFraction::new(0.125),
            CpuFraction::new(0.004),
            now,
            Millis(2000),
        )
    }

    #[test]
    fn boots_then_idle_demand() {
        let p = pe(Millis(0));
        assert_eq!(p.state(), PeState::Booting);
        assert_eq!(p.demand().value(), 0.0);
    }

    #[test]
    fn deliver_only_when_idle() {
        let mut p = pe(Millis(0));
        assert!(p.deliver(msg(1000), Millis(0)).is_err(), "booting rejects");
        p.phase = PePhase::Idle { since: Millis(2000) };
        assert!(p.deliver(msg(1000), Millis(2000)).is_ok());
        assert_eq!(p.state(), PeState::Busy);
        assert!(p.deliver(msg(1000), Millis(2100)).is_err(), "busy rejects");
    }

    #[test]
    fn demand_by_phase() {
        let mut p = pe(Millis(0));
        p.phase = PePhase::Idle { since: Millis(0) };
        assert_eq!(p.demand().value(), 0.004);
        p.deliver(msg(500), Millis(0)).unwrap();
        assert_eq!(p.demand().value(), 0.125);
        p.phase = PePhase::Terminated;
        assert_eq!(p.demand().value(), 0.0);
    }

    #[test]
    fn aux_usage_by_phase() {
        use crate::binpacking::Resource;
        let mut p = ProcessingEngine::with_aux(
            PeId(1),
            ImageName::new("img"),
            CpuFraction::new(0.125),
            ResourceVec::new(0.0, 0.25, 0.05),
            CpuFraction::new(0.004),
            Millis(0),
            Millis(2000),
        );
        assert_eq!(p.aux_usage(), ResourceVec::ZERO, "booting holds nothing");
        p.phase = PePhase::Idle { since: Millis(0) };
        assert_eq!(p.aux_usage(), ResourceVec::ZERO, "idle holds nothing");
        p.deliver(msg(500), Millis(0)).unwrap();
        assert!((p.aux_usage().get(Resource::Ram) - 0.25).abs() < 1e-12);
        assert!((p.aux_usage().get(Resource::Net) - 0.05).abs() < 1e-12);
        p.phase = PePhase::Stopping { until: Millis(100) };
        assert!((p.aux_usage().get(Resource::Ram) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn progress_tracks_remaining_and_resets_on_deliver() {
        let mut p = pe(Millis(0));
        assert_eq!(p.progress(), 0.0, "not busy");
        p.phase = PePhase::Idle { since: Millis(0) };
        p.deliver(msg(1000), Millis(0)).unwrap();
        assert_eq!(p.progress(), 0.0, "just started");
        if let PePhase::Busy { remaining, .. } = &mut p.phase {
            *remaining = Millis(250);
        }
        assert!((p.progress() - 0.75).abs() < 1e-12);
        p.checkpoint = 0.75;
        // Finishing and accepting a new message clears the old snapshot.
        p.phase = PePhase::Idle { since: Millis(1000) };
        p.deliver(msg(1000), Millis(1000)).unwrap();
        assert_eq!(p.checkpoint, 0.0);
    }

    #[test]
    fn cpu_only_pe_has_zero_aux() {
        let mut p = pe(Millis(0));
        p.phase = PePhase::Idle { since: Millis(0) };
        p.deliver(msg(500), Millis(0)).unwrap();
        assert_eq!(p.aux_usage(), ResourceVec::ZERO);
    }
}
