//! Live processing engines: real threads executing the AOT artifacts via
//! PJRT. This is the deployment-mode counterpart of the simulated
//! [`ProcessingEngine`](crate::worker::pe::ProcessingEngine): one OS thread
//! per PE, a bounded mailbox, per-job CPU-time measurement via
//! `clock_gettime(CLOCK_THREAD_CPUTIME_ID)` — the worker half of the
//! paper's profiler, measured for real.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::types::{ImageName, MessageId, PeId};

/// A job for a live PE: one image's pixels to analyze.
pub struct LiveJob {
    pub id: MessageId,
    pub pixels: Vec<f32>,
    pub submitted: Instant,
}

/// Result of one live job.
#[derive(Clone, Debug)]
pub struct LiveResult {
    pub id: MessageId,
    pub pe: PeId,
    /// `[nucleus_count, area_px, mean_fg_intensity, otsu_threshold]`.
    pub features: [f32; 4],
    /// Wall time spent processing (queue wait excluded).
    pub wall: std::time::Duration,
    /// CPU time the PE thread spent on this job.
    pub cpu: std::time::Duration,
    /// End-to-end latency including mailbox wait.
    pub latency: std::time::Duration,
}

/// Thread CPU-time via libc (the real measurement the simulated worker's
/// contention model stands in for).
pub fn thread_cpu_time() -> std::time::Duration {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: plain syscall writing into a stack timespec.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    std::time::Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// A live PE: a worker thread with a bounded mailbox.
pub struct LivePe {
    pub id: PeId,
    pub image: ImageName,
    tx: SyncSender<LiveJob>,
    handle: Option<JoinHandle<()>>,
}

impl LivePe {
    /// Spawn a PE executing the `nuclei` artifact.
    ///
    /// PJRT handles are not `Send`, so each PE thread loads and compiles
    /// its *own* runtime — exactly like each PE container in the paper
    /// runs its own CellProfiler instance. The compile time is the PE's
    /// real "container boot" latency; jobs delivered meanwhile wait in the
    /// mailbox. Results are pushed into `results`.
    // pallas-lint: allow(D4, live-transport endpoint — PE threads wall-clock their own inference, that IS the measurement; sim paths never reach this fn, name-based call resolution only aliases scope.spawn()/thread spawns onto it)
    pub fn spawn(
        id: PeId,
        image: ImageName,
        artifacts_dir: String,
        results: SyncSender<LiveResult>,
    ) -> Result<LivePe> {
        let (tx, rx): (SyncSender<LiveJob>, Receiver<LiveJob>) = sync_channel(1);
        let handle = std::thread::Builder::new()
            .name(format!("{id}"))
            .spawn(move || {
                let runtime = match Runtime::load_dir(&artifacts_dir) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("{id}: runtime load failed: {e:#}");
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let cpu0 = thread_cpu_time();
                    let t0 = Instant::now();
                    match runtime.analyze_image(&job.pixels) {
                        Ok(features) => {
                            let result = LiveResult {
                                id: job.id,
                                pe: id,
                                features,
                                wall: t0.elapsed(),
                                cpu: thread_cpu_time().saturating_sub(cpu0),
                                latency: job.submitted.elapsed(),
                            };
                            if results.send(result).is_err() {
                                break;
                            }
                        }
                        Err(e) => {
                            eprintln!("{id}: job {} failed: {e:#}", job.id);
                        }
                    }
                }
            })?;
        Ok(LivePe {
            id,
            image,
            tx,
            handle: Some(handle),
        })
    }

    /// Non-blocking delivery; returns the job back when the PE is busy
    /// (mailbox full) — the caller requeues on the master backlog, same as
    /// the simulated path.
    pub fn try_deliver(&self, job: LiveJob) -> Result<(), LiveJob> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }

    /// Graceful shutdown (Drop does the same): close the mailbox, join.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for LivePe {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            // The receiver loop ends when every sender is gone; swap our
            // sender for a dummy whose receiver we immediately drop, so
            // the real mailbox closes before the join.
            let (dead_tx, _dead_rx) = sync_channel::<LiveJob>(1);
            let real_tx = std::mem::replace(&mut self.tx, dead_tx);
            drop(real_tx);
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_time_monotonic_and_burns() {
        let a = thread_cpu_time();
        // Burn some CPU.
        let mut acc = 0u64;
        for i in 0..3_000_000u64 {
            acc = acc.wrapping_add(i * 31);
        }
        crate::bench::black_box(acc);
        let b = thread_cpu_time();
        assert!(b > a, "cpu time advanced: {a:?} -> {b:?}");
    }
}
