//! Spark executor model + dynamic allocation policy (the paper's baseline
//! configuration: `spark.dynamicAllocation.*` with
//! `executorIdleTimeout=20s`, exponential ramp-up while the scheduler
//! backlog is sustained, scale-down of idle executors).

use crate::types::Millis;

/// Executor lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecState {
    /// Container/JVM starting; cores not usable yet.
    Starting { usable_at: Millis, registered_at: Millis },
    /// Usable; `registered_at` is when the driver REST API reports it
    /// (slightly after it starts burning CPU — the paper observes "CPU
    /// usage leads the available cores by a few seconds when scaling up").
    Running { registered_at: Millis },
}

/// One executor (a container with `cores` task slots).
#[derive(Clone, Debug)]
pub struct Executor {
    pub id: u64,
    pub cores: u32,
    pub busy: u32,
    /// Of `busy`, how many tasks are still in their input-read (NFS) phase
    /// — they hold a core but burn almost no CPU (the paper's batch-gap
    /// suspect: "The time could have been spent reading the images from
    /// disk").
    pub io_busy: u32,
    pub state: ExecState,
    pub idle_since: Option<Millis>,
}

impl Executor {
    pub fn usable(&self, now: Millis) -> bool {
        match self.state {
            ExecState::Starting { usable_at, .. } => now >= usable_at,
            ExecState::Running { .. } => true,
        }
    }

    pub fn registered(&self, now: Millis) -> bool {
        match self.state {
            ExecState::Starting { registered_at, .. } => now >= registered_at,
            ExecState::Running { registered_at } => now >= registered_at,
        }
    }

    pub fn free_cores(&self, now: Millis) -> u32 {
        if self.usable(now) {
            self.cores - self.busy
        } else {
            0
        }
    }
}

/// Dynamic-allocation policy state (exponential ramp while backlogged).
#[derive(Clone, Debug)]
pub struct DynamicAllocation {
    /// Next ramp round adds up to this many executors (doubles each round).
    ramp: usize,
    backlog_since: Option<Millis>,
    pub backlog_timeout: Millis,
    pub min_executors: usize,
    pub max_executors: usize,
    pub idle_timeout: Millis,
}

impl DynamicAllocation {
    pub fn new(min_executors: usize, max_executors: usize, idle_timeout: Millis) -> Self {
        DynamicAllocation {
            ramp: 1,
            backlog_since: None,
            backlog_timeout: Millis::from_secs(1),
            min_executors,
            max_executors,
            idle_timeout,
        }
    }

    /// How many executors to request this tick given the scheduler backlog
    /// (pending tasks) and current supply. Resets the ramp when the
    /// backlog clears.
    pub fn executors_to_request(
        &mut self,
        now: Millis,
        pending_tasks: usize,
        current: usize,
        cores_per_exec: u32,
    ) -> usize {
        if pending_tasks == 0 {
            self.backlog_since = None;
            self.ramp = 1;
            return 0;
        }
        match self.backlog_since {
            None => {
                self.backlog_since = Some(now);
                0
            }
            Some(since) if now >= since + self.backlog_timeout => {
                self.backlog_since = Some(now); // next round re-arms
                let need = pending_tasks.div_ceil(cores_per_exec as usize);
                let want = (current + self.ramp).min(self.max_executors).min(
                    // Never request beyond what the backlog justifies.
                    current.max(need).max(self.min_executors),
                );
                let add = want.saturating_sub(current);
                // Cap the exponential ramp: doubling past the executor cap
                // is pointless (and would overflow on long backlogs).
                self.ramp = (self.ramp * 2).min(self.max_executors.max(1));
                add
            }
            Some(_) => 0,
        }
    }

    /// Which executors to release: idle past the timeout, respecting the
    /// minimum (the paper's red-circled scale-downs).
    pub fn executors_to_release(&self, now: Millis, executors: &[Executor]) -> Vec<u64> {
        let mut releasable: Vec<&Executor> = executors
            .iter()
            .filter(|e| e.busy == 0)
            .filter(|e| {
                e.idle_since
                    .map(|t0| now >= t0 + self.idle_timeout)
                    .unwrap_or(false)
            })
            .collect();
        releasable.sort_by_key(|e| e.id);
        releasable.reverse(); // newest first
        let max_release = executors.len().saturating_sub(self.min_executors);
        releasable
            .into_iter()
            .take(max_release)
            .map(|e| e.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec(id: u64, busy: u32, idle_since: Option<Millis>) -> Executor {
        Executor {
            id,
            cores: 8,
            busy,
            io_busy: 0,
            state: ExecState::Running {
                registered_at: Millis(0),
            },
            idle_since,
        }
    }

    #[test]
    fn ramp_doubles_while_backlogged() {
        let mut da = DynamicAllocation::new(1, 16, Millis::from_secs(20));
        // t=0: backlog noticed, nothing yet.
        assert_eq!(da.executors_to_request(Millis(0), 100, 1, 8), 0);
        // After the backlog timeout: +1, then +2, then +4…
        assert_eq!(da.executors_to_request(Millis(1000), 100, 1, 8), 1);
        assert_eq!(da.executors_to_request(Millis(2000), 100, 2, 8), 2);
        assert_eq!(da.executors_to_request(Millis(3000), 100, 4, 8), 4);
        // need = ceil(100/8) = 13 caps the next round at 13 total → +5.
        assert_eq!(da.executors_to_request(Millis(4000), 100, 8, 8), 5);
    }

    #[test]
    fn ramp_capped_by_max_and_need() {
        let mut da = DynamicAllocation::new(1, 5, Millis::from_secs(20));
        da.executors_to_request(Millis(0), 100, 1, 8);
        // need = ceil(100/8) = 13 > max 5 → capped at 5 total.
        assert_eq!(da.executors_to_request(Millis(1000), 100, 4, 8), 1);
        // Small backlog: 4 tasks on 1 executor of 8 cores → no growth
        // beyond need=1.
        let mut da = DynamicAllocation::new(1, 5, Millis::from_secs(20));
        da.executors_to_request(Millis(0), 4, 1, 8);
        assert_eq!(da.executors_to_request(Millis(1000), 4, 1, 8), 0);
    }

    #[test]
    fn backlog_clear_resets_ramp() {
        let mut da = DynamicAllocation::new(1, 16, Millis::from_secs(20));
        da.executors_to_request(Millis(0), 10, 1, 8);
        da.executors_to_request(Millis(1000), 10, 1, 8);
        assert_eq!(da.executors_to_request(Millis(2000), 0, 2, 8), 0);
        // Backlog returns: ramp restarts at 1.
        da.executors_to_request(Millis(3000), 50, 2, 8);
        assert_eq!(da.executors_to_request(Millis(4000), 50, 2, 8), 1);
    }

    #[test]
    fn idle_executors_released_after_timeout() {
        let da = DynamicAllocation::new(1, 5, Millis::from_secs(20));
        let executors = vec![
            exec(0, 4, None),
            exec(1, 0, Some(Millis(0))),
            exec(2, 0, Some(Millis::from_secs(15))),
        ];
        let released = da.executors_to_release(Millis::from_secs(21), &executors);
        assert_eq!(released, vec![1], "only the 20s-idle one");
    }

    #[test]
    fn min_executors_respected() {
        let da = DynamicAllocation::new(1, 5, Millis::from_secs(20));
        let executors = vec![exec(0, 0, Some(Millis(0)))];
        let released = da.executors_to_release(Millis::from_secs(60), &executors);
        assert!(released.is_empty(), "never below min");
    }

    #[test]
    fn executor_visibility_lag() {
        let e = Executor {
            id: 0,
            cores: 8,
            busy: 0,
            io_busy: 0,
            state: ExecState::Starting {
                usable_at: Millis(4000),
                registered_at: Millis(7000),
            },
            idle_since: None,
        };
        assert!(!e.usable(Millis(3000)));
        assert!(e.usable(Millis(4000)));
        // CPU can burn (usable) before the REST API shows the cores.
        assert!(!e.registered(Millis(5000)));
        assert!(e.registered(Millis(7000)));
        assert_eq!(e.free_cores(Millis(3000)), 0);
        assert_eq!(e.free_cores(Millis(5000)), 8);
    }
}
