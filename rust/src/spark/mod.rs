//! Apache Spark Streaming baseline (paper §VI-B1, Fig 7).
//!
//! A discrete-time model of the paper's comparison system: Spark file
//! streaming with 5 s micro-batches, CellProfiler invoked per image as an
//! external process (one task = one image = one core, "the minimum unit of
//! parallelism"), `spark.streaming.concurrentJobs=3`, and the *older*
//! dynamic-allocation policy (`executorIdleTimeout=20s`, exponential
//! ramp-up on sustained scheduler backlog) the paper had to fall back to.
//!
//! Reproduced phenomena (all visible in the recorded series):
//! * executor cores staircase up to the cluster cap;
//! * measured CPU *leads* reported cores during ramp-up (executors burn
//!   CPU before the REST API registers them);
//! * per-batch CPU gaps (job submit + NFS image reads before compute);
//! * idle-gap-triggered scale-downs (the red circles of Fig 7).

pub mod executor;

use std::collections::VecDeque;

use crate::clock::Periodic;
use crate::metrics::Recorder;
use crate::sim::{Arrival, EventQueue};
use crate::types::{IdGen, Millis};
use crate::util::rng::Rng;
use crate::workload::Trace;

pub use executor::{DynamicAllocation, ExecState, Executor};

/// Baseline configuration (defaults = the paper's settings).
#[derive(Clone, Debug)]
pub struct SparkConfig {
    /// Micro-batch interval (5 s in the paper).
    pub batch_interval: Millis,
    /// `spark.streaming.concurrentJobs` (raised to 3 in the paper).
    pub concurrent_jobs: usize,
    /// Cores per executor (SSC.xlarge = 8).
    pub executor_cores: u32,
    /// Worker VMs (the 5-worker cap shared with the HIO experiment).
    pub max_executors: usize,
    pub min_executors: usize,
    /// `spark.dynamicAllocation.executorIdleTimeout` (20 s in the paper).
    pub executor_idle_timeout: Millis,
    /// JVM/executor spin-up before tasks can run.
    pub executor_startup: Millis,
    /// Lag until the driver REST API reports a new executor's cores.
    pub registration_lag: Millis,
    /// Per-job overhead before its tasks are runnable (job submit).
    pub job_setup: (Millis, Millis),
    /// Per-task input-read (NFS) phase: the task holds a core at ~zero CPU
    /// before compute starts — the paper's suspected gap source.
    pub task_io: (Millis, Millis),
    /// Result collection/teardown at the end of each job; the job keeps its
    /// concurrency slot (driver busy) for this long after its last task.
    pub collect_overhead: (Millis, Millis),
    /// The paper's observed anomaly: "For unknown reasons, the system sat
    /// idle with 2 executors for some time." Modelled as a driver stall
    /// (no task scheduling) of this duration after the first job
    /// completes. Set to 0 to disable.
    pub driver_stall: Millis,
    pub seed: u64,
}

impl Default for SparkConfig {
    fn default() -> Self {
        SparkConfig {
            batch_interval: Millis::from_secs(5),
            concurrent_jobs: 3,
            executor_cores: 8,
            max_executors: 5,
            min_executors: 1,
            executor_idle_timeout: Millis::from_secs(20),
            executor_startup: Millis::from_secs(4),
            registration_lag: Millis::from_secs(5),
            job_setup: (Millis::from_secs(2), Millis::from_secs(6)),
            task_io: (Millis::from_secs(2), Millis::from_secs(6)),
            collect_overhead: (Millis::from_secs(4), Millis::from_secs(10)),
            driver_stall: Millis::from_secs(75),
            seed: 11,
        }
    }
}

/// One micro-batch job.
#[derive(Clone, Debug)]
struct Job {
    /// Remaining task costs (one per image still waiting for a core).
    pending: VecDeque<Millis>,
    running: usize,
    /// Tasks become runnable only after setup (job submit).
    runnable_at: Millis,
    /// Set when the last task finishes: the driver still collects results
    /// until this time and the job keeps its concurrency slot.
    collect_until: Option<Millis>,
}

impl Job {
    fn tasks_finished(&self) -> bool {
        self.pending.is_empty() && self.running == 0
    }

    fn done(&self, now: Millis) -> bool {
        self.tasks_finished() && self.collect_until.map(|t| now >= t).unwrap_or(false)
    }
}

/// A recorded scale-down event (Fig 7's red circles).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleDown {
    pub at: Millis,
    pub executors_left: usize,
}

/// The Spark Streaming baseline simulator.
pub struct SparkSim {
    pub cfg: SparkConfig,
    pub recorder: Recorder,
    pub scale_downs: Vec<ScaleDown>,
    arrivals: EventQueue<Arrival>,
    unbatched: Vec<Arrival>,
    jobs: VecDeque<Job>,
    active: Vec<Job>,
    executors: Vec<Executor>,
    exec_ids: IdGen,
    allocation: DynamicAllocation,
    /// (finish_at-keyed) running task completions; payload = executor id.
    task_done: EventQueue<u64>,
    /// End of each running task's input-read phase; payload = executor id.
    io_done: EventQueue<u64>,
    batch_timer: Periodic,
    sample_timer: Periodic,
    rng: Rng,
    pub tasks_total: usize,
    pub tasks_completed: usize,
    pub last_completion: Millis,
    /// Driver stall window (the paper's unexplained idle period).
    stall_until: Option<Millis>,
    stall_spent: bool,
    jobs_completed: usize,
    now: Millis,
}

impl SparkSim {
    pub fn new(cfg: SparkConfig) -> Self {
        let allocation = DynamicAllocation::new(
            cfg.min_executors,
            cfg.max_executors,
            cfg.executor_idle_timeout,
        );
        SparkSim {
            recorder: Recorder::new(),
            scale_downs: Vec::new(),
            arrivals: EventQueue::new(),
            unbatched: Vec::new(),
            jobs: VecDeque::new(),
            active: Vec::new(),
            executors: Vec::new(),
            exec_ids: IdGen::new(),
            allocation,
            task_done: EventQueue::new(),
            io_done: EventQueue::new(),
            batch_timer: Periodic::new(cfg.batch_interval),
            sample_timer: Periodic::new(Millis::from_secs(1)),
            rng: Rng::seeded(cfg.seed),
            tasks_total: 0,
            tasks_completed: 0,
            last_completion: Millis::ZERO,
            stall_until: None,
            stall_spent: false,
            jobs_completed: 0,
            now: Millis::ZERO,
            cfg,
        }
    }

    /// Load a workload trace (files appearing in the source directory).
    pub fn load_trace(&mut self, trace: &Trace) {
        for (t, a) in &trace.arrivals {
            self.arrivals.schedule(*t, a.clone());
            self.tasks_total += 1;
        }
        // Spark starts with the minimum executors already registered.
        for _ in 0..self.cfg.min_executors {
            self.spawn_executor(Millis::ZERO, true);
        }
    }

    fn spawn_executor(&mut self, now: Millis, warm: bool) {
        let id = self.exec_ids.next_id();
        let state = if warm {
            ExecState::Running { registered_at: now }
        } else {
            ExecState::Starting {
                usable_at: now + self.cfg.executor_startup,
                registered_at: now + self.cfg.executor_startup + self.cfg.registration_lag,
            }
        };
        self.executors.push(Executor {
            id,
            cores: self.cfg.executor_cores,
            busy: 0,
            io_busy: 0,
            state,
            idle_since: Some(now),
        });
    }

    /// Advance to `now` (monotonic).
    pub fn tick(&mut self, now: Millis) {
        self.now = now;

        // 1a. Input-read phases ending (task switches to compute).
        for (_, exec_id) in self.io_done.pop_due(now) {
            if let Some(e) = self.executors.iter_mut().find(|e| e.id == exec_id) {
                e.io_busy = e.io_busy.saturating_sub(1);
            }
        }

        // 1b. Task completions.
        for (at, exec_id) in self.task_done.pop_due(now) {
            if let Some(e) = self.executors.iter_mut().find(|e| e.id == exec_id) {
                e.busy -= 1;
                if e.busy == 0 {
                    e.idle_since = Some(at);
                }
            }
            for job in &mut self.active {
                if job.running > 0 {
                    job.running -= 1;
                    break;
                }
            }
            self.tasks_completed += 1;
            self.last_completion = at;
        }
        // Jobs whose last task just finished enter result collection; the
        // first finished job triggers the paper's observed driver stall.
        let mut start_stall = false;
        for job in &mut self.active {
            if job.tasks_finished() && job.collect_until.is_none() {
                let collect = Millis(self.rng.range(
                    self.cfg.collect_overhead.0 .0,
                    self.cfg.collect_overhead.1 .0,
                ));
                job.collect_until = Some(now + collect);
                self.jobs_completed += 1;
                if self.jobs_completed == 1
                    && !self.stall_spent
                    && self.cfg.driver_stall.0 > 0
                {
                    start_stall = true;
                }
            }
        }
        if start_stall {
            self.stall_spent = true;
            self.stall_until = Some(now + self.cfg.driver_stall);
        }
        self.active.retain(|j| !j.done(now));

        // 2. New files → unbatched pool; batch boundary → job.
        for (_, a) in self.arrivals.pop_due(now) {
            self.unbatched.push(a);
        }
        if self.batch_timer.fire(now) && !self.unbatched.is_empty() {
            let setup = Millis(
                self.rng
                    .range(self.cfg.job_setup.0 .0, self.cfg.job_setup.1 .0),
            );
            let job = Job {
                pending: self
                    .unbatched
                    .drain(..)
                    .map(|a| a.service_demand)
                    .collect(),
                running: 0,
                runnable_at: now + setup,
                collect_until: None,
            };
            self.jobs.push_back(job);
        }

        // 3. Activate jobs up to concurrentJobs.
        while self.active.len() < self.cfg.concurrent_jobs {
            match self.jobs.pop_front() {
                Some(job) => self.active.push(job),
                None => break,
            }
        }

        // 4. Schedule tasks of runnable active jobs onto free cores (the
        // driver schedules nothing during its stall window).
        let stalled = self.stall_until.map(|t| now < t).unwrap_or(false);
        for job in &mut self.active {
            if stalled || now < job.runnable_at {
                continue;
            }
            'fill: while !job.pending.is_empty() {
                let slot = self
                    .executors
                    .iter_mut()
                    .filter(|e| e.free_cores(now) > 0)
                    .min_by_key(|e| e.id);
                match slot {
                    Some(e) => {
                        let cost = job.pending.pop_front().unwrap();
                        e.busy += 1;
                        e.io_busy += 1;
                        e.idle_since = None;
                        job.running += 1;
                        let eid = e.id;
                        let io = Millis(
                            self.rng.range(self.cfg.task_io.0 .0, self.cfg.task_io.1 .0),
                        );
                        self.io_done.schedule(now + io, eid);
                        self.task_done.schedule(now + io + cost, eid);
                    }
                    None => break 'fill,
                }
            }
        }

        // 5. Dynamic allocation.
        let pending: usize = self
            .active
            .iter()
            .map(|j| j.pending.len())
            .sum::<usize>()
            + self.jobs.iter().map(|j| j.pending.len()).sum::<usize>();
        let add = self.allocation.executors_to_request(
            now,
            pending,
            self.executors.len(),
            self.cfg.executor_cores,
        );
        for _ in 0..add {
            if self.executors.len() < self.cfg.max_executors {
                self.spawn_executor(now, false);
            }
        }
        let release = self.allocation.executors_to_release(now, &self.executors);
        if !release.is_empty() {
            self.executors.retain(|e| !release.contains(&e.id));
            self.scale_downs.push(ScaleDown {
                at: now,
                executors_left: self.executors.len(),
            });
        }

        // 6. Sample Fig 7 series.
        if self.sample_timer.fire(now) {
            let registered_cores: u32 = self
                .executors
                .iter()
                .filter(|e| e.registered(now))
                .map(|e| e.cores)
                .sum();
            let compute: u32 = self.executors.iter().map(|e| e.busy - e.io_busy).sum();
            let io: u32 = self.executors.iter().map(|e| e.io_busy).sum();
            let busy_cores = compute as f64 + 0.1 * io as f64;
            let noise = self.rng.normal_with(0.0, 0.15).max(-0.5);
            self.recorder
                .record("spark.executor_cores", now, registered_cores as f64);
            self.recorder.record(
                "spark.cpu_cores",
                now,
                (busy_cores + noise).max(0.0),
            );
            self.recorder.record("spark.pending_tasks", now, pending as f64);
            self.recorder
                .record("spark.active_jobs", now, self.active.len() as f64);
        }
    }

    /// Run until all tasks complete (or deadline); returns the makespan.
    pub fn run_to_completion(&mut self, dt: Millis, deadline: Millis) -> Option<Millis> {
        let mut t = self.now;
        if t == Millis::ZERO {
            self.tick(Millis::ZERO);
        }
        while self.tasks_completed < self.tasks_total && t < deadline {
            t = t + dt;
            self.tick(t);
        }
        (self.tasks_completed >= self.tasks_total).then_some(self.last_completion)
    }

    pub fn executors(&self) -> &[Executor] {
        &self.executors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{MicroscopyConfig, MicroscopyTrace};

    fn microscopy_run(n_images: usize) -> SparkSim {
        let trace = MicroscopyTrace::new(MicroscopyConfig {
            n_images,
            stream_rate_per_sec: 10.0,
            ..MicroscopyConfig::default()
        })
        .run_trace(0);
        let mut sim = SparkSim::new(SparkConfig::default());
        sim.load_trace(&trace);
        sim
    }

    #[test]
    fn completes_all_tasks() {
        let mut sim = microscopy_run(120);
        let makespan = sim
            .run_to_completion(Millis(100), Millis::from_secs(2000))
            .expect("all tasks complete");
        assert!(makespan > Millis::from_secs(30));
        assert_eq!(sim.tasks_completed, 120);
    }

    #[test]
    fn scales_up_to_cap_under_load() {
        let mut sim = microscopy_run(400);
        sim.run_to_completion(Millis(100), Millis::from_secs(3000))
            .unwrap();
        let cores = sim.recorder.get("spark.executor_cores").unwrap().max();
        assert_eq!(cores, 40.0, "all 5×8 cores registered at peak");
    }

    #[test]
    fn scale_downs_happen(){
        let mut sim = microscopy_run(300);
        sim.run_to_completion(Millis(100), Millis::from_secs(3000))
            .unwrap();
        // Run past the idle timeout to see the tail scale-down.
        let end = sim.now + Millis::from_secs(60);
        let mut t = sim.now;
        while t < end {
            t = t + Millis(100);
            sim.tick(t);
        }
        assert!(!sim.scale_downs.is_empty(), "Fig 7 red circles exist");
        // Never below min executors.
        assert!(sim.executors().len() >= 1);
    }

    #[test]
    fn cpu_leads_registered_cores_during_rampup() {
        let mut sim = microscopy_run(400);
        sim.run_to_completion(Millis(100), Millis::from_secs(3000))
            .unwrap();
        // Find a moment where busy cores exceed registered cores.
        let cpu = sim.recorder.get("spark.cpu_cores").unwrap();
        let cores = sim.recorder.get("spark.executor_cores").unwrap();
        let lead = cpu
            .points
            .iter()
            .any(|(t, busy)| cores.at(*t).map(|c| *busy > c + 0.5).unwrap_or(false));
        assert!(lead, "CPU must lead registered cores during ramp-up");
    }

    #[test]
    fn respects_max_executors() {
        let mut sim = microscopy_run(500);
        sim.run_to_completion(Millis(100), Millis::from_secs(4000))
            .unwrap();
        assert!(sim.executors().len() <= 5);
    }

    #[test]
    fn batch_gaps_visible_in_cpu() {
        let mut sim = microscopy_run(300);
        sim.run_to_completion(Millis(100), Millis::from_secs(3000))
            .unwrap();
        // During the busy middle phase the CPU series must dip well below
        // its peak at least once (the paper's inter-batch gaps).
        let cpu = sim.recorder.get("spark.cpu_cores").unwrap();
        let peak = cpu.max();
        let end = cpu.end().unwrap();
        let mid: Vec<f64> = cpu
            .points
            .iter()
            .filter(|(t, _)| t.0 > end.0 / 5 && t.0 < 4 * end.0 / 5)
            .map(|(_, v)| *v)
            .collect();
        let dip = mid.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            dip < peak * 0.75,
            "no gap visible: dip {dip:.1} vs peak {peak:.1}"
        );
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut sim = microscopy_run(150);
            sim.run_to_completion(Millis(100), Millis::from_secs(3000))
                .map(|m| m.0)
        };
        assert_eq!(run(), run());
    }
}
