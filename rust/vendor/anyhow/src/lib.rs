//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! the subset of the `anyhow` 1.x API the codebase uses: [`Error`] with a
//! context chain, the [`Result`] alias, the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain joined by `: `, and `{:?}` prints
//! the message followed by a `Caused by:` list.
//!
//! Swap this path dependency for the real crate if the build environment
//! ever gains registry access — no call sites need to change.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same defaulted error type as anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context message (what `.context(..)` does).
    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The messages in the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow: any std error converts, capturing its source chain. `Error`
// itself deliberately does NOT implement `std::error::Error`, which is what
// keeps this blanket impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(context().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+).into())
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+).into());
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("missing file"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_lazy() {
        let v: Result<u32> = Ok(7);
        let called = std::cell::Cell::new(false);
        let v = v.with_context(|| {
            called.set(true);
            "ctx"
        });
        assert_eq!(v.unwrap(), 7);
        assert!(!called.get(), "context closure must be lazy");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", n))
        }
        assert_eq!(fails(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(fails(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(fails(1).unwrap_err().to_string(), "fell through with 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn chain_and_root_cause() {
        let e: Error = Err::<(), _>(io_err())
            .context("inner")
            .context("outer")
            .unwrap_err();
        let msgs: Vec<&str> = e.chain().collect();
        assert_eq!(msgs, vec!["outer", "inner", "missing file"]);
        assert_eq!(e.root_cause(), "missing file");
    }
}
