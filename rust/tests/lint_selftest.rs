//! Self-test for the `pallas-lint` engine: every fixture under
//! `rust/tests/lint_fixtures/` declares its own expected findings inline,
//! so the corpus doubles as executable documentation of each rule.
//!
//! Fixture format:
//! * line 1 is `//@ virtual-path: <rel>` — the path under `rust/src/` the
//!   snippet pretends to live at (drives module-scope classification);
//! * any line may end with `//~ RULE [RULE…]` — the findings expected on
//!   exactly that line;
//! * a fixture with no markers asserts zero findings (a negative case).
//!
//! The corpus is excluded from both the normal and `--deep` tree scans
//! (it is known-bad on purpose) and from compilation (`Cargo.toml`
//! declares targets explicitly), so planting violations there is safe.

use harmonicio::lint;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures")
}

fn fixtures() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(fixture_dir())
        .expect("fixture corpus present")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("rs"))
        .collect();
    out.sort();
    assert!(out.len() >= 10, "fixture corpus unexpectedly small: {}", out.len());
    out
}

/// Pull the virtual path out of the header line and the `(line, rule)`
/// expectation set out of the `//~` markers.
fn parse_expectations(src: &str) -> (String, BTreeSet<(u32, String)>) {
    let header = src.lines().next().expect("non-empty fixture");
    let rel = header
        .strip_prefix("//@ virtual-path: ")
        .expect("fixture must start with `//@ virtual-path: <rel>`")
        .trim()
        .to_string();
    let mut expected = BTreeSet::new();
    for (idx, line) in src.lines().enumerate() {
        if let Some(pos) = line.rfind("//~ ") {
            for rule in line[pos + 4..].split_whitespace() {
                expected.insert((idx as u32 + 1, rule.to_string()));
            }
        }
    }
    (rel, expected)
}

#[test]
fn fixtures_produce_exactly_the_marked_findings() {
    for path in fixtures() {
        let src = std::fs::read_to_string(&path).unwrap();
        let (rel, expected) = parse_expectations(&src);
        let got: BTreeSet<(u32, String)> = lint::lint_virtual(&rel, &src)
            .into_iter()
            .map(|f| (f.line, f.rule.to_string()))
            .collect();
        assert_eq!(
            got,
            expected,
            "fixture {} (linted as {rel}) disagrees with its //~ markers",
            path.display()
        );
    }
}

#[test]
fn every_rule_has_fixture_coverage() {
    let mut hit: BTreeSet<String> = BTreeSet::new();
    for path in fixtures() {
        let src = std::fs::read_to_string(&path).unwrap();
        let (_, expected) = parse_expectations(&src);
        hit.extend(expected.into_iter().map(|(_, rule)| rule));
    }
    for (id, _) in lint::RULES {
        assert!(hit.contains(*id), "no fixture exercises rule {id}");
    }
}

#[test]
fn binary_is_clean_on_this_repo() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .arg(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn pallas_lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "pallas_lint found violations in the tree:\n{stdout}");
    assert!(stdout.contains("0 findings"), "unexpected summary:\n{stdout}");
}

#[test]
fn binary_fails_on_a_known_bad_fixture() {
    let fixture = fixture_dir().join("p1_unwrap_hot.rs");
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .args(["--file", fixture.to_str().unwrap(), "--as", "cloud/p1_unwrap_hot.rs"])
        .output()
        .expect("spawn pallas_lint");
    assert_eq!(out.status.code(), Some(1), "known-bad fixture must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("P1"), "expected P1 findings:\n{stdout}");
}

#[test]
fn deep_scan_is_clean_and_deterministic() {
    let run = || {
        Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
            .args(["--deep", env!("CARGO_MANIFEST_DIR")])
            .output()
            .expect("spawn pallas_lint")
    };
    let first = run();
    assert!(
        first.status.success(),
        "deep scan found violations:\n{}",
        String::from_utf8_lossy(&first.stdout)
    );
    let second = run();
    assert_eq!(first.stdout, second.stdout, "lint output must be byte-identical across runs");
}

#[test]
fn rules_flag_prints_the_catalog() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .arg("--rules")
        .output()
        .expect("spawn pallas_lint");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for (id, _) in lint::RULES {
        assert!(stdout.contains(id), "catalog missing rule {id}:\n{stdout}");
    }
}
