//! Self-test for the `pallas-lint` engine: every fixture under
//! `rust/tests/lint_fixtures/` declares its own expected findings inline,
//! so the corpus doubles as executable documentation of each rule.
//!
//! Fixture format:
//! * every section starts with `//@ virtual-path: <rel>` — the path under
//!   `rust/src/` the snippet pretends to live at (drives module-scope
//!   classification). A fixture may hold several sections; they are linted
//!   together as one crate, which is how the cross-file D4 taint chains
//!   are exercised without planting bad code in the real tree;
//! * any line may end with `//~ RULE [RULE…]` — the findings expected on
//!   exactly that line of its section;
//! * a fixture with no markers asserts zero findings (a negative case).
//!
//! The corpus is excluded from both the normal and `--deep` tree scans
//! (it is known-bad on purpose) and from compilation (`Cargo.toml`
//! declares targets explicitly), so planting violations there is safe.

use harmonicio::lint;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures")
}

fn fixtures() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(fixture_dir())
        .expect("fixture corpus present")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("rs"))
        .collect();
    out.sort();
    assert!(out.len() >= 18, "fixture corpus unexpectedly small: {}", out.len());
    out
}

/// One fixture section: its virtual path, its source text (starting at the
/// `//@` header, so marker lines are 1-based within the section), and the
/// `(line, rule)` expectations from the `//~` markers.
struct Section {
    rel: String,
    src: String,
    expected: BTreeSet<(u32, String)>,
}

fn parse_sections(src: &str) -> Vec<Section> {
    let mut raw: Vec<(String, Vec<&str>)> = Vec::new();
    for line in src.lines() {
        if let Some(rest) = line.strip_prefix("//@ virtual-path: ") {
            raw.push((rest.trim().to_string(), vec![line]));
        } else {
            raw.last_mut()
                .expect("fixture must start with `//@ virtual-path: <rel>`")
                .1
                .push(line);
        }
    }
    assert!(!raw.is_empty(), "fixture declares no virtual path");
    raw.into_iter()
        .map(|(rel, lines)| {
            let mut expected = BTreeSet::new();
            for (idx, line) in lines.iter().enumerate() {
                if let Some(pos) = line.rfind("//~ ") {
                    for rule in line[pos + 4..].split_whitespace() {
                        expected.insert((idx as u32 + 1, rule.to_string()));
                    }
                }
            }
            Section { rel, src: lines.join("\n"), expected }
        })
        .collect()
}

/// Lint a fixture's sections together as one crate (the cross-file call
/// graph sees all of them) and return the `(file, line, rule)` set.
fn lint_fixture(sections: &[Section]) -> BTreeSet<(String, u32, String)> {
    let inputs: Vec<lint::Input> = sections
        .iter()
        .map(|s| lint::Input {
            rel: s.rel.clone(),
            display: s.rel.clone(),
            src: s.src.clone(),
            ctx: lint::FileCtx::Source,
        })
        .collect();
    lint::lint_crate(&inputs)
        .into_iter()
        .map(|f| (f.file, f.line, f.rule.to_string()))
        .collect()
}

#[test]
fn fixtures_produce_exactly_the_marked_findings() {
    for path in fixtures() {
        let src = std::fs::read_to_string(&path).unwrap();
        let sections = parse_sections(&src);
        let got = lint_fixture(&sections);
        let expected: BTreeSet<(String, u32, String)> = sections
            .iter()
            .flat_map(|s| {
                s.expected.iter().map(|(l, r)| (s.rel.clone(), *l, r.clone()))
            })
            .collect();
        assert_eq!(
            got,
            expected,
            "fixture {} disagrees with its //~ markers",
            path.display()
        );
    }
}

#[test]
fn every_rule_has_fixture_coverage() {
    let mut hit: BTreeSet<String> = BTreeSet::new();
    for path in fixtures() {
        let src = std::fs::read_to_string(&path).unwrap();
        for s in parse_sections(&src) {
            hit.extend(s.expected.into_iter().map(|(_, rule)| rule));
        }
    }
    for (id, _) in lint::RULES {
        assert!(hit.contains(*id), "no fixture exercises rule {id}");
    }
}

#[test]
fn d4_reports_the_full_call_chain() {
    let src =
        std::fs::read_to_string(fixture_dir().join("d4_taint_chain.rs")).unwrap();
    let sections = parse_sections(&src);
    let inputs: Vec<lint::Input> = sections
        .iter()
        .map(|s| lint::Input {
            rel: s.rel.clone(),
            display: s.rel.clone(),
            src: s.src.clone(),
            ctx: lint::FileCtx::Source,
        })
        .collect();
    let findings = lint::lint_crate(&inputs);
    let d4 = findings
        .iter()
        .find(|f| f.rule == "D4")
        .expect("taint-chain fixture must produce a D4 finding");
    assert_eq!(d4.file, "sim/tick_taint.rs");
    let hops: Vec<&str> = d4.chain.iter().map(String::as_str).collect();
    assert_eq!(hops.len(), 4, "two-hop chain plus sink: {hops:?}");
    assert!(hops[0].ends_with("tick_all"), "chain starts at the flagged fn: {hops:?}");
    assert!(hops[1].contains("stamp_ms") && hops[1].starts_with("util/stamp.rs:"));
    assert!(hops[2].contains("raw_now_ms") && hops[2].starts_with("clock/real_source.rs:"));
    assert_eq!(hops[3], "Instant::now");
    assert!(
        d4.message.contains("`tick_all` -> `stamp_ms` -> `raw_now_ms` -> `Instant::now`"),
        "message must print the chain: {}",
        d4.message
    );
}

#[test]
fn binary_is_clean_on_this_repo() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .arg(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn pallas_lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "pallas_lint found violations in the tree:\n{stdout}");
    assert!(stdout.contains("0 findings"), "unexpected summary:\n{stdout}");
}

#[test]
fn binary_fails_on_a_known_bad_fixture() {
    let fixture = fixture_dir().join("p1_unwrap_hot.rs");
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .args(["--file", fixture.to_str().unwrap(), "--as", "cloud/p1_unwrap_hot.rs"])
        .output()
        .expect("spawn pallas_lint");
    assert_eq!(out.status.code(), Some(1), "known-bad fixture must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("P1"), "expected P1 findings:\n{stdout}");
}

#[test]
fn json_format_emits_machine_readable_findings() {
    let fixture = fixture_dir().join("p1_unwrap_hot.rs");
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .args([
            "--format",
            "json",
            "--file",
            fixture.to_str().unwrap(),
            "--as",
            "cloud/p1_unwrap_hot.rs",
        ])
        .output()
        .expect("spawn pallas_lint");
    assert_eq!(out.status.code(), Some(1), "findings still drive the exit code");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.starts_with('{'), "expected a JSON object:\n{stdout}");
    for key in ["\"count\"", "\"scanned\"", "\"findings\"", "\"rule\"", "\"chain\""] {
        assert!(stdout.contains(key), "JSON output missing {key}:\n{stdout}");
    }
    assert!(stdout.contains("\"P1\""), "expected P1 in JSON:\n{stdout}");
}

#[test]
fn deep_scan_is_clean_and_deterministic() {
    let run = || {
        Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
            .args(["--deep", env!("CARGO_MANIFEST_DIR")])
            .output()
            .expect("spawn pallas_lint")
    };
    let first = run();
    assert!(
        first.status.success(),
        "deep scan found violations:\n{}",
        String::from_utf8_lossy(&first.stdout)
    );
    let second = run();
    assert_eq!(first.stdout, second.stdout, "lint output must be byte-identical across runs");
}

#[test]
fn rules_flag_prints_the_catalog() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas_lint"))
        .arg("--rules")
        .output()
        .expect("spawn pallas_lint");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for (id, _) in lint::RULES {
        assert!(stdout.contains(id), "catalog missing rule {id}:\n{stdout}");
    }
}
