//! Allocation-counting pin for the `SeriesId` interner (PR 9 satellite):
//! after warm-up the recorder must never re-`format!` or re-intern a
//! series name — recording through interned ids costs only the amortized
//! growth of the per-series point vectors, and a warm cluster's tick
//! loop stays allocation-free at steady state.
//!
//! The counting allocator is process-global, so this binary holds a
//! single test walking both scopes sequentially — a second `#[test]`
//! would run on a sibling thread and pollute the counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use harmonicio::cloud::CloudConfig;
use harmonicio::metrics::Recorder;
use harmonicio::sim::{Arrival, ClusterConfig, EventCore, SimCluster};
use harmonicio::types::{ImageName, Millis};
use harmonicio::worker::WorkerConfig;

struct CountingAlloc;

/// Heap acquisitions (alloc + realloc); frees are not counted.
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

#[test]
fn interned_series_keep_steady_state_allocation_free() {
    // --- Recorder scope: recording through interned ids never touches
    // the name map. 30 series × 1000 points costs only the point
    // vectors' amortized doubling (~7 reallocs per series); rebuilding
    // names per record (the pre-interner behavior) costs ≥ 1 allocation
    // per record — 30 000 here — so the bound separates cleanly.
    let mut rec = Recorder::new();
    let ids: Vec<_> = (0..30).map(|i| rec.series_id(&format!("s{i}"))).collect();
    for t in 0..10u64 {
        for id in &ids {
            rec.record_id(*id, Millis(t), t as f64);
        }
    }
    let before = alloc_calls();
    for t in 10..1010u64 {
        for id in &ids {
            rec.record_id(*id, Millis(t), t as f64);
        }
    }
    let delta = alloc_calls() - before;
    assert!(
        delta < 2_000,
        "30k interned records cost {delta} allocations — series names are being \
         rebuilt per record (that regression costs ≥ 30000)"
    );

    // --- Cluster scope: warm a cluster through a full burst (every slot
    // and fixed series interned, every reusable tick buffer grown, the
    // fleet scaled back down), then demand that a long steady-state
    // window allocates essentially nothing. A format!-per-sample
    // regression alone costs ≥ 21 allocations per sample (12 fixed + 3
    // per slot × 3 slots) — ≥ 2100 over the 100-sample window measured
    // here — so the 1000 bound cannot mask it.
    let mut cfg = ClusterConfig::default();
    cfg.event_core = EventCore::Wheel;
    cfg.cloud = CloudConfig {
        quota: 3,
        boot_delay: Millis::from_secs(5),
        boot_jitter: Millis(1000),
        ..CloudConfig::default()
    };
    cfg.worker = WorkerConfig {
        container_boot: Millis(2000),
        container_boot_jitter: Millis(500),
        container_idle_timeout: Millis::from_secs(5),
        image_pull: Millis::ZERO,
        measure_noise_std: 0.0,
        ..WorkerConfig::default()
    };
    let mut c = SimCluster::new(cfg);
    for _ in 0..30 {
        c.schedule_arrival(
            Millis(0),
            Arrival {
                image: ImageName::new("img"),
                payload_bytes: 1 << 20,
                service_demand: Millis::from_secs(5),
            },
        );
    }
    c.run_until(Millis::from_secs(240));
    assert_eq!(c.completions.len(), 30, "warm-up drained the burst");
    let names_before = c.recorder.names().len();
    let before = alloc_calls();
    c.run_until(Millis::from_secs(340));
    let delta = alloc_calls() - before;
    assert_eq!(
        c.recorder.names().len(),
        names_before,
        "steady state interned a new series name"
    );
    assert!(
        delta < 1_000,
        "1000 steady-state ticks cost {delta} allocations — the tick loop or \
         recorder is allocating per tick/sample again"
    );
}
