//! Placement-equivalence properties: the indexed packing engine must make
//! **exactly** the same decisions as the naive reference scans, for every
//! rule, over random item streams — including pre-populated initial bins
//! (the IRM packs new requests around live workers) and across live-engine
//! scheduling rounds (`sync_used`). All properties are seeded via testkit
//! (`TESTKIT_SEED`/`TESTKIT_CASES` env knobs).

use harmonicio::binpacking::{
    BestFit, Bin, BinPacker, EngineRule, FirstFit, FirstFitDecreasing, FirstFitTree, Harmonic,
    IndexedPacker, Item, NextFit, PackEngine, WorstFit,
};
use harmonicio::testkit::{self, Config};
use harmonicio::util::rng::Rng;

fn items(sizes: &[f64]) -> Vec<Item> {
    sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| Item::new(i as u64, s))
        .collect()
}

fn bins(loads: &[f64]) -> Vec<Bin> {
    loads.iter().map(|&u| Bin::with_used(u)).collect()
}

/// Random instance: pre-loaded worker bins + an item stream. Roughly a
/// quarter of the bins are exactly empty (idle workers) — that exercises
/// Harmonic's claim-an-empty-bin path and zero-residual edge cases.
fn gen_instance(rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
    let loads: Vec<f64> = (0..rng.below(15))
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                rng.uniform(0.0, 1.0)
            }
        })
        .collect();
    let sizes = testkit::gen_item_sizes(rng, 80);
    (loads, sizes)
}

/// The (naive oracle, indexed) pairs under test.
fn pairs() -> Vec<(Box<dyn BinPacker>, Box<dyn BinPacker>)> {
    vec![
        (Box::new(FirstFit), Box::new(IndexedPacker::first())),
        (Box::new(FirstFit), Box::new(FirstFitTree)),
        (Box::new(NextFit), Box::new(IndexedPacker::next())),
        (Box::new(BestFit), Box::new(IndexedPacker::best())),
        (Box::new(WorstFit), Box::new(IndexedPacker::worst())),
        (Box::new(Harmonic { k: 7 }), Box::new(IndexedPacker::harmonic(7))),
        (Box::new(Harmonic { k: 3 }), Box::new(IndexedPacker::harmonic(3))),
    ]
}

#[test]
fn prop_indexed_pack_equals_naive_pack() {
    testkit::forall_no_shrink(
        Config {
            cases: 300,
            ..Config::default()
        },
        gen_instance,
        |(loads, sizes)| {
            let its = items(sizes);
            for (naive, indexed) in pairs() {
                let a = naive.pack(&its, bins(loads));
                let b = indexed.pack(&its, bins(loads));
                a.check(&its).map_err(|e| format!("{}: {e}", naive.name()))?;
                b.check(&its)
                    .map_err(|e| format!("{}: {e}", indexed.name()))?;
                if a.assignments != b.assignments {
                    return Err(format!(
                        "{} vs {} diverged:\n  naive   {:?}\n  indexed {:?}",
                        naive.name(),
                        indexed.name(),
                        a.assignments,
                        b.assignments
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_one_stream_equals_batch_pack() {
    // Feeding the stream one item at a time through `pack_one` (in-place,
    // no re-pack) must reproduce the batch placements, for every packer.
    let packers: Vec<Box<dyn BinPacker>> = vec![
        Box::new(FirstFit),
        Box::new(NextFit),
        Box::new(BestFit),
        Box::new(WorstFit),
        Box::new(Harmonic { k: 7 }),
        Box::new(FirstFitTree),
        Box::new(IndexedPacker::best()),
        Box::new(IndexedPacker::worst()),
        Box::new(IndexedPacker::harmonic(7)),
    ];
    testkit::forall_no_shrink(
        Config {
            cases: 200,
            ..Config::default()
        },
        gen_instance,
        |(loads, sizes)| {
            let its = items(sizes);
            for p in &packers {
                let batch = p.pack(&its, bins(loads));
                let mut live = bins(loads);
                let mut one_by_one = Vec::with_capacity(its.len());
                for item in &its {
                    one_by_one.push(p.pack_one(*item, &mut live));
                }
                if batch.assignments != one_by_one {
                    return Err(format!(
                        "{}: batch {:?} != pack_one {:?}",
                        p.name(),
                        batch.assignments,
                        one_by_one
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_insert_equals_naive_pack() {
    // The stateful engine (what the allocator holds) against the oracle.
    let rules: Vec<(EngineRule, Box<dyn BinPacker>)> = vec![
        (EngineRule::First, Box::new(FirstFit)),
        (EngineRule::Next, Box::new(NextFit)),
        (EngineRule::Best, Box::new(BestFit)),
        (EngineRule::Worst, Box::new(WorstFit)),
        (EngineRule::Harmonic(5), Box::new(Harmonic { k: 5 })),
    ];
    testkit::forall_no_shrink(
        Config {
            cases: 200,
            ..Config::default()
        },
        gen_instance,
        |(loads, sizes)| {
            let its = items(sizes);
            for (rule, naive) in &rules {
                let mut engine = PackEngine::new(*rule, bins(loads));
                let got: Vec<usize> = its.iter().map(|it| engine.insert(*it)).collect();
                let want = naive.pack(&its, bins(loads)).assignments;
                if got != want {
                    return Err(format!(
                        "engine {rule:?}: {got:?} != naive {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_live_engine_rounds_equal_fresh_packs() {
    // The IRM pattern: one engine reconciled to new worker loads every
    // scheduling round must place like a from-scratch pack each round.
    let rules: Vec<(EngineRule, Box<dyn BinPacker>)> = vec![
        (EngineRule::First, Box::new(FirstFit)),
        (EngineRule::Best, Box::new(BestFit)),
        (EngineRule::Worst, Box::new(WorstFit)),
        (EngineRule::Harmonic(7), Box::new(Harmonic { k: 7 })),
    ];
    testkit::forall_no_shrink(
        Config {
            cases: 60,
            ..Config::default()
        },
        |rng| {
            let rounds = 1 + rng.below(5) as usize;
            (0..rounds).map(|_| gen_instance(rng)).collect::<Vec<_>>()
        },
        |rounds| {
            for (rule, naive) in &rules {
                let mut engine = PackEngine::new(*rule, Vec::new());
                for (loads, sizes) in rounds {
                    let its = items(sizes);
                    engine.sync_used(loads.iter().copied());
                    let got: Vec<usize> = its.iter().map(|it| engine.insert(*it)).collect();
                    let want = naive.pack(&its, bins(loads)).assignments;
                    if got != want {
                        return Err(format!(
                            "live engine {rule:?} diverged on a later round: {got:?} != {want:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ffd_matches_naive_oracle() {
    // FFD's inner First-Fit now runs on the engine; against the spelled-
    // out offline oracle (stable sort by decreasing size + naive FF scan).
    testkit::forall_no_shrink(
        Config {
            cases: 200,
            ..Config::default()
        },
        gen_instance,
        |(loads, sizes)| {
            let its = items(sizes);
            let got = FirstFitDecreasing.pack(&its, bins(loads));
            got.check(&its).map_err(|e| format!("ffd: {e}"))?;

            let mut order: Vec<usize> = (0..its.len()).collect();
            order.sort_by(|&a, &b| its[b].size.total_cmp(&its[a].size));
            let sorted: Vec<Item> = order.iter().map(|&i| its[i]).collect();
            let oracle = FirstFit.pack(&sorted, bins(loads));
            let mut want = vec![0usize; its.len()];
            for (pos, &orig) in order.iter().enumerate() {
                want[orig] = oracle.assignments[pos];
            }
            if got.assignments != want {
                return Err(format!("ffd {:?} != oracle {want:?}", got.assignments));
            }
            Ok(())
        },
    );
}

#[test]
fn indexed_scales_textbook_case() {
    // Deterministic sanity: a large stream through the indexed engine
    // stays placement-identical to the naive scan (10⁴ items is naive-
    // feasible in a test; the benches push 10⁵–10⁶).
    let mut rng = Rng::seeded(0xBEEF);
    let sizes: Vec<f64> = (0..10_000)
        .map(|_| {
            if rng.next_f64() < 0.8 {
                rng.uniform(0.08, 0.2)
            } else {
                rng.uniform(0.2, 0.9)
            }
        })
        .collect();
    let its = items(&sizes);
    for (naive, indexed) in pairs() {
        let a = naive.pack(&its, Vec::new());
        let b = indexed.pack(&its, Vec::new());
        assert_eq!(
            a.assignments,
            b.assignments,
            "{} vs {}",
            naive.name(),
            indexed.name()
        );
        assert_eq!(a.bins_used(), b.bins_used());
    }
}
