//! Distributed-mode integration: the live cluster served over TCP, a
//! stream-connector client talking the JSON wire protocol from another
//! thread (the `repro serve` / `repro stream` path).

use std::sync::{Arc, Mutex};

use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::transport;
use harmonicio::util::json::Json;
use harmonicio::workload::ImageGen;

#[test]
fn serve_analyze_and_status_over_tcp() {
    let cluster = match LiveCluster::new(
        "artifacts",
        LiveConfig {
            max_pes: 2,
            initial_pes: 1,
            scale_up_backlog_per_pe: 2,
        },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping tcp test: {e:#}");
            return;
        }
    };
    let cluster = Arc::new(Mutex::new(cluster));
    let server = LiveCluster::serve(cluster, "127.0.0.1:0").unwrap();
    let addr = server.addr();

    // Analyze one image end to end through the wire protocol.
    let mut gen = ImageGen::new(5, 128);
    let planted = 20;
    let pixels = gen.generate(planted);
    let req = Json::obj([
        ("type", Json::str("analyze")),
        (
            "pixels",
            Json::arr(pixels.iter().map(|p| Json::num(*p as f64))),
        ),
    ]);
    let resp = transport::call(addr, &req).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp}");
    let features = resp.get("features").unwrap().as_arr().unwrap();
    assert_eq!(features.len(), 4);
    let count = features[0].as_f64().unwrap();
    assert!(
        count >= planted as f64 * 0.5 && count <= planted as f64 * 1.5 + 2.0,
        "planted {planted}, counted {count}"
    );

    // Status endpoint.
    let status = transport::call(addr, &Json::obj([("type", Json::str("status"))])).unwrap();
    assert_eq!(status.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(status.get("completed").and_then(|v| v.as_u64()), Some(1));

    // Unknown request type is rejected, not a crash.
    let bad = transport::call(addr, &Json::obj([("type", Json::str("nope"))])).unwrap();
    assert_eq!(bad.get("ok").and_then(|v| v.as_bool()), Some(false));

    server.shutdown();
}
