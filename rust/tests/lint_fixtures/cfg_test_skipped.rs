//@ virtual-path: sim/cfg_test_skipped.rs
//! Negative: `#[cfg(test)]` / `#[test]` items are exempt from the
//! catalog — a panic in a test is the test failing, not a run dying.

fn hot(o: Option<u32>) -> u32 {
    o.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
