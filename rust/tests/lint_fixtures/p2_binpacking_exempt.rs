//@ virtual-path: binpacking/p2_binpacking_exempt.rs
//! Negative: index arithmetic is the bin-packing kernel's idiom and the
//! kernel is property-tested against naive oracles, so P2 exempts it.

fn load(bins: &[f64], idx: usize) -> f64 {
    bins[idx]
}
