//@ virtual-path: clock/real_cache.rs
//! Allowlisted wall-clock source feeding the sanitizer case below.
use std::time::Instant;

pub fn raw_ms(epoch: Instant) -> u64 {
    Instant::now().duration_since(epoch).as_millis() as u64
}
//@ virtual-path: util/cached_stamp.rs
//! A D4 pragma is a taint *sanitizer*: the argued fn neither flags nor
//! conducts, so the determinism-critical caller below stays clean. The
//! reason must argue byte-identity, not convenience.
use std::time::Instant;

// pallas-lint: allow(D4, returns a value cached before the sim loop starts — byte-identical across runs for a fixed config)
pub fn cached_ms(epoch: Instant) -> u64 {
    raw_ms(epoch)
}
//@ virtual-path: sim/uses_cache.rs
//! Negative: the only path to the sink goes through the sanitized fn.
use std::time::Instant;

pub fn tick_stamp(epoch: Instant) -> u64 {
    cached_ms(epoch)
}
