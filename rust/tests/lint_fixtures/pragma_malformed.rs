//@ virtual-path: metrics/pragma_malformed.rs
//! True positives: a pragma without a reason, or naming an unknown rule,
//! is itself a finding (rule LINT) — suppressions must be auditable.

// pallas-lint: allow(P2) //~ LINT

// pallas-lint: allow(Q9, no such rule) //~ LINT

fn noop() {}
