//@ virtual-path: irm/d2_wallclock.rs
//! True positives: wall-clock and ambient entropy on a sim-reachable path.
//! These make runs irreproducible; sim code must take time from the
//! virtual Clock and randomness from the seeded util::rng::Rng.

fn elapsed_ns() -> u128 {
    let t0 = std::time::Instant::now(); //~ D2
    t0.elapsed().as_nanos()
}

fn wall_secs() -> u64 {
    let now = std::time::SystemTime::now(); //~ D2
    match now.duration_since(std::time::UNIX_EPOCH) {
        Ok(d) => d.as_secs(),
        Err(_) => 0,
    }
}

fn roll() -> u64 {
    let mut r = rand::thread_rng(); //~ D2
    r.gen()
}
