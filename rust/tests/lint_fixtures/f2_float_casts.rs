//@ virtual-path: worker/f2_float_casts.rs
//! True positives: bare `as` casts on float expressions. `f64 as u64`
//! maps NaN to 0 silently — the PR 5 bug class — so float-typed values
//! must route through util::cast, which debug-asserts the precondition.

fn quantize(x: f64) -> u64 {
    (x * 1000.0).round() as u64 //~ F2
}

fn bucket(x: f64) -> usize {
    x.floor() as usize //~ F2
}

fn ok_int(n: usize) -> u64 {
    n as u64
}
