//@ virtual-path: sim/p2_indexing.rs
//! True positive: direct indexing in a scheduling-plane module; the
//! `.get()` form on the same data is the clean alternative.

fn pick(workers: &[u32], pos: usize) -> u32 {
    workers[pos] //~ P2
}

fn safe(workers: &[u32], pos: usize) -> u32 {
    workers.get(pos).copied().unwrap_or(0)
}
