//@ virtual-path: sim/d3_conditional_draw.rs
//! D3 — RNG-draw discipline. A seeded draw inside an `if`/`match` arm
//! (or a `?`-guarded statement) advances the stream on one path and not
//! the other, forking every later consumer's values — the hazard-0 bug
//! class. Loops are exempt (per-item draws repeat with the item count),
//! and a pragma arguing draw-count identity across arms suppresses.
use crate::util::rng::Rng;

pub fn arm_draw(rng: &mut Rng, enabled: bool) -> u64 {
    if enabled {
        rng.next_u64() //~ D3
    } else {
        0
    }
}

pub fn per_item(rng: &mut Rng, n: usize) -> u64 {
    let mut acc = 0;
    for _ in 0..n {
        // Loops are exempt: the draw count follows the (deterministic)
        // item count, not a config arm.
        acc ^= rng.next_u64();
    }
    acc
}

pub fn guarded(rng: &mut Rng, v: Option<u64>) -> Option<u64> {
    Some(v? ^ rng.next_u64()) //~ D3
}

pub fn argued(rng: &mut Rng, noise_std: f64) -> f64 {
    if noise_std > 0.0 {
        // pallas-lint: allow(D3, condition is static config — every call in a run takes the same arm, so the per-call draw count is constant)
        rng.normal_with(0.0, noise_std)
    } else {
        0.0
    }
}
