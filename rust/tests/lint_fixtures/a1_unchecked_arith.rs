//@ virtual-path: irm/a1_unchecked.rs
//! A1 — unchecked integer arithmetic in the scheduling plane. `-` fires
//! on either-side integer evidence (underflow lives at 0, the common end
//! of the unsigned range — the E9 warmup_stats class); `+`/`*` only when
//! both operands are typed integers. Newtype wrappers with overloaded
//! operators are exempt, but raw `.0` access on one is integer evidence
//! again. `checked_*`/`saturating_*` and invariant pragmas are the two
//! sanctioned exits.
pub struct Span(pub u64);

pub fn shrink(total: usize, used: usize) -> usize {
    total - used //~ A1
}

pub fn last_index(xs: &[u64]) -> usize {
    xs.len() - 1 //~ A1
}

pub fn grow(a: u64, b: u64) -> u64 {
    a + b //~ A1
}

pub fn scale(a: u64, b: u64) -> u64 {
    a * b //~ A1
}

pub fn wrapper_exempt(a: Span, b: Span) -> u64 {
    let d = a - b; // overloaded Sub saturates by design — no finding
    d.0
}

pub fn wrapper_raw(a: Span, b: Span) -> u64 {
    a.0 - b.0 //~ A1
}

pub fn wrapper_literal(a: Span) -> u64 {
    a.0 - 1 //~ A1
}

pub fn hardened(total: usize, used: usize) -> usize {
    total.saturating_sub(used)
}

pub fn argued(cap: usize, used: usize) -> usize {
    // pallas-lint: allow(A1, used <= cap is checked at admission — the subtraction cannot underflow)
    cap - used
}
//@ virtual-path: binpacking/a1_exempt.rs
//! Negative: the bin-packing kernel is outside A1 scope — index
//! arithmetic is its idiom and it is property-tested against oracles.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}
