//@ virtual-path: metrics/f1_partial_cmp.rs
//! F1 fires everywhere (not just critical modules): a float sort through
//! `partial_cmp(..).unwrap()` panics on the first NaN. A hand-written
//! `partial_cmp` that provably delegates to a total order may be
//! pragma'd.

fn sort_scores(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); //~ F1
}

struct Key(u64);

impl PartialOrd for Key {
    // pallas-lint: allow(F1, delegates to the total Ord impl over u64)
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.0.cmp(&other.0))
    }
}
