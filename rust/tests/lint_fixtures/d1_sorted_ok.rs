//@ virtual-path: sim/d1_sorted_ok.rs
//! Negatives: the collect-then-sort idiom and BTree containers are both
//! deterministic, so D1 stays quiet.

use std::collections::{BTreeMap, HashMap};

fn ordered_keys(m: &HashMap<u64, f64>) -> Vec<u64> {
    let mut ks: Vec<u64> = m.keys().copied().collect();
    ks.sort_unstable();
    ks
}

fn walk(bt: &BTreeMap<u64, f64>) -> f64 {
    bt.values().sum()
}
