//@ virtual-path: clock/real_source.rs
//! Allowlisted: the real clock IS wall time, so neither D2 nor D4 fires
//! here — but the call graph still carries taint *through* this file to
//! any determinism-critical caller outside the allowlist.
use std::time::Instant;

pub fn raw_now_ms(epoch: Instant) -> u64 {
    Instant::now().duration_since(epoch).as_millis() as u64
}
//@ virtual-path: util/stamp.rs
//! Neither critical nor allowlisted: clean on its own, but a conduit —
//! the chain below passes through it untouched.
use std::time::Instant;

pub fn stamp_ms(epoch: Instant) -> u64 {
    raw_now_ms(epoch)
}
//@ virtual-path: sim/tick_taint.rs
//! Determinism-critical and two hops from the sink: D4 reports the full
//! chain (tick_all -> stamp_ms -> raw_now_ms -> Instant::now) even
//! though every intermediate file is clean on its own.
use std::time::Instant;

pub fn tick_all(epoch: Instant) -> u64 { //~ D4
    stamp_ms(epoch)
}
//@ virtual-path: irm/direct_sink.rs
//! A *direct* sink in critical scope is D2's finding; D4 requires at
//! least one call edge, so it stays quiet on this fn.
pub fn entropy_seed() -> u64 {
    let _r = rand::thread_rng(); //~ D2
    0
}
