//@ virtual-path: irm/pragma_attr_adjacency.rs
//! Pragma adjacency binds *through* attribute and doc-comment lines to
//! the next code line, so annotating above a `#[derive(...)]`/`#[inline]`
//! block still covers the item. Blank lines and ordinary `//` comments
//! are NOT transparent: adjacency is the audit trail, and a pragma
//! drifting away from its item must stop suppressing.

// pallas-lint: allow(P1, the runtime invariant holds by construction here; this pragma binds through the attribute and doc lines below)
#[inline]
/// Doc comment between the attribute and the item.
fn covered(v: Option<u64>) -> u64 { v.unwrap() }

// pallas-lint: allow(P1, a blank line below breaks adjacency — this pragma covers nothing)

fn gapped(v: Option<u64>) -> u64 { v.unwrap() } //~ P1
