//@ virtual-path: bench/d2_allowlisted.rs
//! Negative: the bench harness measures wall time by definition, so the
//! same code that is a D2 violation in `irm/` is clean here.

fn measure() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
