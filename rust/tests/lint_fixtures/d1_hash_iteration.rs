//@ virtual-path: sim/d1_hash_iteration.rs
//! True positives: HashMap/HashSet iteration in a determinism-critical
//! module. Iteration order depends on the hasher's per-process seed, so
//! any behavior fed by it breaks the seed-42 golden snapshots.

use std::collections::{HashMap, HashSet};

struct State {
    scores: HashMap<u64, f64>,
}

impl State {
    fn total(&self) -> f64 {
        let mut acc = 0.0;
        for (_, v) in &self.scores { //~ D1
            acc += v;
        }
        acc
    }

    fn prune(&mut self) {
        self.scores.retain(|_, v| *v > 0.5); //~ D1
    }
}

fn visit(seen: HashSet<u64>) -> Vec<u64> {
    seen.into_iter().collect() //~ D1
}
