//@ virtual-path: irm/fanout.rs
//! D2 also covers OS-thread fan-out: `thread::spawn` / `thread::scope`
//! entry points outside the live allowlist must pragma the argument for
//! why the merge order is fixed (nondeterministic interleaving otherwise).

fn par(xs: &mut Vec<u32>) {
    std::thread::scope(|s| { //~ D2
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}

fn ok() {
    // pallas-lint: allow(D2, single worker joined immediately — merge order is trivial)
    let h = std::thread::spawn(|| 1u32);
    let _ = h.join();
}
