//@ virtual-path: irm/pragma_file_level.rs
//! Negative: a file-level pragma with a written-down reason suppresses
//! its rule across the whole file.

// pallas-lint: allow-file(P2, indices are produced by enumerate() over the same vector)

fn sum_at(xs: &[f64], picks: &[usize]) -> f64 {
    let mut acc = 0.0;
    for &i in picks {
        acc += xs[i];
    }
    acc
}
