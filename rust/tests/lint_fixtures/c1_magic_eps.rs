//@ virtual-path: binpacking/c1_magic_eps.rs
//! True positive: an unnamed epsilon-magnitude tolerance literal in
//! behavior-feeding code (the PR 2 bug class: duplicated tolerances
//! drift apart). Naming it in a `const` or consuming it inside an
//! `assert!` check is fine.

pub const EPS: f64 = 1e-9;

fn nearly_full(residual: f64) -> bool {
    residual <= 1e-9 //~ C1
}

fn check(over: f64) {
    assert!(over <= 1e-6, "invariant holds to checker slack");
}
