//@ virtual-path: cloud/p1_unwrap_hot.rs
//! True positives: panicking Option/Result access in a hot-path module
//! kills a multi-hour experiment run mid-flight.

fn first_price(prices: &[f64]) -> f64 {
    *prices.first().unwrap() //~ P1
}

fn parse_quota(s: &str) -> u32 {
    s.parse().expect("quota must be an integer") //~ P1
}

fn safe(prices: &[f64]) -> f64 {
    prices.first().copied().unwrap_or(0.0)
}
