//! Multi-dimensional placement-equivalence properties: the indexed vector
//! engine ([`VecPackEngine`]) must make **exactly** the same decisions as
//! the naive oracles — First-, Next-, Best-, Worst-Fit and Harmonic(k) —
//! over random vector item streams and random *flavor mixes*
//! (heterogeneous bin capacities, pre-loaded bins, live-engine rounds
//! through `sync`) — the vector mirror of
//! `rust/tests/binpacking_equivalence.rs`. Any failure prints a
//! `TESTKIT_SEED=…` line that reproduces it with one env var.

use harmonicio::binpacking::{
    first_fit_md_in, first_fit_md_indexed, pack_md_in, pack_md_indexed, FirstFit, Item,
    ResourceVec, VecBin, VecItem, VecPackEngine, VecRule,
};
use harmonicio::testkit::{self, Config};
use harmonicio::util::rng::Rng;

/// Every vector rule under test (the scalar family's twins).
const RULES: [VecRule; 6] = [
    VecRule::First,
    VecRule::Next,
    VecRule::Best,
    VecRule::Worst,
    VecRule::Harmonic(3),
    VecRule::Harmonic(7),
];

/// The flavor palette instances draw from (reference = the unit flavor;
/// mirrors the SSC flavors plus an odd asymmetric one).
const FLAVORS: [ResourceVec; 4] = [
    ResourceVec([1.0, 1.0, 1.0]),
    ResourceVec([0.5, 0.5, 1.0]),
    ResourceVec([0.125, 0.125, 1.0]),
    ResourceVec([0.75, 0.4, 0.6]),
];

fn rand_flavor(rng: &mut Rng) -> ResourceVec {
    FLAVORS[rng.below(FLAVORS.len() as u64) as usize]
}

/// Random instance: a flavor mix of pre-loaded bins (about a quarter
/// exactly empty — idle workers), an item stream that always fits the
/// provisioning flavor, and the provisioning flavor itself.
#[allow(clippy::type_complexity)]
fn gen_instance(rng: &mut Rng) -> (Vec<(ResourceVec, ResourceVec)>, Vec<ResourceVec>, ResourceVec) {
    let new_capacity = rand_flavor(rng);
    let bins: Vec<(ResourceVec, ResourceVec)> = (0..rng.below(12))
        .map(|_| {
            let cap = rand_flavor(rng);
            let used = if rng.below(4) == 0 {
                ResourceVec::ZERO
            } else {
                ResourceVec::new(
                    rng.uniform(0.0, cap.0[0]),
                    rng.uniform(0.0, cap.0[1]),
                    rng.uniform(0.0, cap.0[2]),
                )
            };
            (cap, used)
        })
        .collect();
    let items: Vec<ResourceVec> = (0..rng.below(60))
        .map(|_| {
            // CPU is always demanded (a container without CPU does not
            // exist). Most items fit the provisioning flavor; the rest
            // range up to the full reference VM, exercising the
            // larger-live-flavor fit and the clamp-at-open paths.
            if rng.below(4) == 0 {
                ResourceVec::new(
                    rng.uniform(0.01, 1.0),
                    rng.uniform(0.0, 1.0),
                    rng.uniform(0.0, 1.0),
                )
            } else {
                ResourceVec::new(
                    rng.uniform(0.01, new_capacity.0[0]),
                    rng.uniform(0.0, new_capacity.0[1]),
                    rng.uniform(0.0, new_capacity.0[2]),
                )
            }
        })
        .collect();
    (bins, items, new_capacity)
}

fn materialize(bins: &[(ResourceVec, ResourceVec)]) -> Vec<VecBin> {
    bins.iter()
        .map(|(cap, used)| VecBin::with_load(*cap, *used))
        .collect()
}

fn vec_items(sizes: &[ResourceVec]) -> Vec<VecItem> {
    sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| VecItem::new(i as u64, s))
        .collect()
}

#[test]
fn prop_indexed_pack_equals_naive_pack() {
    testkit::forall_no_shrink(
        Config {
            cases: 300,
            ..Config::default()
        },
        gen_instance,
        |(bins, sizes, new_cap)| {
            let its = vec_items(sizes);
            let a = first_fit_md_in(&its, materialize(bins), *new_cap);
            let b = first_fit_md_indexed(&its, materialize(bins), *new_cap);
            a.check(&its).map_err(|e| format!("naive: {e}"))?;
            b.check(&its).map_err(|e| format!("indexed: {e}"))?;
            if a.assignments != b.assignments {
                return Err(format!(
                    "diverged (new_cap {new_cap}):\n  naive   {:?}\n  indexed {:?}",
                    a.assignments, b.assignments
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_insert_stream_equals_batch() {
    // Feeding items one at a time through a held engine must reproduce the
    // batch placements (the IRM inserts per request).
    testkit::forall_no_shrink(
        Config {
            cases: 200,
            ..Config::default()
        },
        gen_instance,
        |(bins, sizes, new_cap)| {
            let its = vec_items(sizes);
            let mut engine = VecPackEngine::new(materialize(bins), *new_cap);
            let got: Vec<usize> = its.iter().map(|it| engine.insert(*it)).collect();
            let want = first_fit_md_in(&its, materialize(bins), *new_cap).assignments;
            if got != want {
                return Err(format!("engine {got:?} != naive {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_live_engine_rounds_equal_fresh_packs() {
    // The IRM pattern: one engine reconciled (`sync`) to a new worker
    // population every round must place like a from-scratch pack.
    testkit::forall_no_shrink(
        Config {
            cases: 60,
            ..Config::default()
        },
        |rng| {
            let rounds = 1 + rng.below(5) as usize;
            (0..rounds).map(|_| gen_instance(rng)).collect::<Vec<_>>()
        },
        |rounds| {
            let mut engine = VecPackEngine::new(Vec::new(), ResourceVec::UNIT);
            for (bins, sizes, _new_cap) in rounds {
                // The provisioning flavor is fixed per engine; the worker
                // population (flavor mix) changes every round.
                let its = vec_items(sizes);
                engine.sync(
                    bins.iter()
                        .map(|(cap, used)| (*used, *cap))
                        .collect::<Vec<_>>(),
                );
                let got: Vec<usize> = its.iter().map(|it| engine.insert(*it)).collect();
                let want =
                    first_fit_md_in(&its, materialize(bins), ResourceVec::UNIT).assignments;
                if got != want {
                    return Err(format!(
                        "live engine diverged on a later round: {got:?} != {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vec_rules_equal_their_naive_oracles() {
    // The ISSUE-3 acceptance gate: ≥ 500 random cases in the default run
    // (TESTKIT_CASES raises it further — the ci_check.sh --deep budget
    // applies here), every rule placement-identical to its naive oracle
    // over random flavor mixes, pre-loaded bins and clamp-at-open item
    // streams.
    testkit::forall_no_shrink(
        Config {
            cases: Config::default().cases.max(520),
            ..Config::default()
        },
        gen_instance,
        |(bins, sizes, new_cap)| {
            let its = vec_items(sizes);
            for rule in RULES {
                let a = pack_md_in(rule, &its, materialize(bins), *new_cap);
                let b = pack_md_indexed(rule, &its, materialize(bins), *new_cap);
                a.check(&its).map_err(|e| format!("{rule:?} naive: {e}"))?;
                b.check(&its).map_err(|e| format!("{rule:?} indexed: {e}"))?;
                if a.assignments != b.assignments {
                    return Err(format!(
                        "{rule:?} diverged (new_cap {new_cap}):\n  naive   {:?}\n  indexed {:?}",
                        a.assignments, b.assignments
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vec_rules_equal_oracles_on_generated_profiles() {
    // Same equivalence over the shared testkit generator (shrinkable item
    // streams, unit bins) — a failing stream shrinks to a minimal one.
    testkit::forall(
        Config {
            cases: Config::default().cases.max(150),
            ..Config::default()
        },
        |rng| testkit::gen_resource_vecs(rng, 40),
        testkit::shrink_resource_vecs,
        |sizes| {
            let its = vec_items(sizes);
            for rule in RULES {
                let a = pack_md_in(rule, &its, Vec::new(), ResourceVec::UNIT);
                let b = pack_md_indexed(rule, &its, Vec::new(), ResourceVec::UNIT);
                if a.assignments != b.assignments {
                    return Err(format!(
                        "{rule:?}: naive {:?} != indexed {:?}",
                        a.assignments, b.assignments
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_live_engine_rounds_equal_fresh_packs_per_rule() {
    // The IRM pattern for every rule: one engine reconciled (`sync`) to a
    // new worker population each round must place like a from-scratch
    // pack with that rule. Budgeted at a fifth of the configured cases
    // (each case is a multi-round, multi-rule pack) so the --deep pass
    // still scales it.
    testkit::forall_no_shrink(
        Config {
            cases: (Config::default().cases / 5).max(40),
            ..Config::default()
        },
        |rng| {
            let rounds = 1 + rng.below(4) as usize;
            (0..rounds).map(|_| gen_instance(rng)).collect::<Vec<_>>()
        },
        |rounds| {
            for rule in RULES {
                let mut engine = VecPackEngine::with_rule(rule, Vec::new(), ResourceVec::UNIT);
                for (bins, sizes, _new_cap) in rounds {
                    let its = vec_items(sizes);
                    engine.sync(
                        bins.iter()
                            .map(|(cap, used)| (*used, *cap))
                            .collect::<Vec<_>>(),
                    );
                    let got: Vec<usize> = its.iter().map(|it| engine.insert(*it)).collect();
                    let want =
                        pack_md_in(rule, &its, materialize(bins), ResourceVec::UNIT).assignments;
                    if got != want {
                        return Err(format!(
                            "{rule:?} live engine diverged on a later round: {got:?} != {want:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cpu_only_items_reduce_to_scalar_first_fit() {
    // With zero RAM/net demand and unit bins, vector First-Fit must be
    // indistinguishable from the scalar engine's First-Fit.
    testkit::forall_no_shrink(
        Config {
            cases: 150,
            ..Config::default()
        },
        |rng| testkit::gen_item_sizes(rng, 60),
        |sizes| {
            let md: Vec<VecItem> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| VecItem::new(i as u64, ResourceVec::cpu(s)))
                .collect();
            let scalar: Vec<Item> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| Item::new(i as u64, s))
                .collect();
            use harmonicio::binpacking::BinPacker;
            let a = first_fit_md_indexed(&md, Vec::new(), ResourceVec::UNIT);
            let b = FirstFit.pack(&scalar, Vec::new());
            if a.assignments != b.assignments {
                return Err(format!(
                    "vector {:?} != scalar {:?}",
                    a.assignments, b.assignments
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn indexed_scales_on_a_large_heterogeneous_stream() {
    // Deterministic sanity at a size where the naive scan is still
    // feasible: 10⁴ RAM-heavy items over a flavor mix.
    let mut rng = Rng::seeded(0xD1CE);
    let (bins, _, _) = gen_instance(&mut rng);
    let sizes: Vec<ResourceVec> = (0..10_000)
        .map(|_| {
            ResourceVec::new(
                rng.uniform(0.01, 0.2),
                rng.uniform(0.0, 0.35),
                rng.uniform(0.0, 0.1),
            )
        })
        .collect();
    let its = vec_items(&sizes);
    let a = first_fit_md_in(&its, materialize(&bins), ResourceVec::UNIT);
    let b = first_fit_md_indexed(&its, materialize(&bins), ResourceVec::UNIT);
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.bins_used(), b.bins_used());
}
