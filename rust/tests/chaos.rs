//! Failure injection: worker crashes mid-run must not lose messages, and
//! the IRM must restore capacity (the paper's reliability premise —
//! "recovery from failures" is table stakes for streaming frameworks).

use harmonicio::cloud::CloudConfig;
use harmonicio::experiments::microscopy;
use harmonicio::sim::{Arrival, ClusterConfig, SimCluster};
use harmonicio::types::{ImageName, Millis, WorkerId};
use harmonicio::util::rng::Rng;
use harmonicio::worker::WorkerConfig;

fn fast_cluster(quota: usize) -> SimCluster {
    let mut cfg: ClusterConfig = microscopy::cluster_config(99);
    cfg.cloud = CloudConfig {
        quota,
        boot_delay: Millis::from_secs(8),
        boot_jitter: Millis(2000),
        ..CloudConfig::default()
    };
    cfg.worker = WorkerConfig {
        container_boot: Millis(2000),
        container_boot_jitter: Millis(500),
        container_idle_timeout: Millis::from_secs(5),
        image_pull: Millis::ZERO,
        measure_noise_std: 0.0,
        ..WorkerConfig::default()
    };
    SimCluster::new(cfg)
}

fn burst(c: &mut SimCluster, n: usize, demand_s: u64) {
    for _ in 0..n {
        c.schedule_arrival(
            Millis(0),
            Arrival {
                image: ImageName::new("cellprofiler:3.1.9"),
                payload_bytes: 4 << 20,
                service_demand: Millis::from_secs(demand_s),
            },
        );
    }
}

#[test]
fn single_worker_crash_loses_nothing() {
    let mut c = fast_cluster(4);
    burst(&mut c, 80, 10);
    // Let the cluster ramp up and get busy.
    c.run_until(Millis::from_secs(60));
    assert!(!c.workers().is_empty());
    let victim = c.workers()[0].id;
    assert!(c.fail_worker(victim));
    assert_eq!(
        c.accounted_messages(),
        80,
        "crash must not lose messages"
    );
    // Everything still completes.
    let makespan = c.run_to_completion(80, Millis::from_secs(2000));
    assert!(makespan.is_some(), "all 80 messages completed after crash");
}

#[test]
fn repeated_random_crashes_still_drain() {
    let mut c = fast_cluster(4);
    burst(&mut c, 60, 8);
    let mut rng = Rng::seeded(7);
    let mut t = Millis::ZERO;
    let mut crashes = 0;
    // Crash a random worker every ~30 s of sim time, five times.
    for _ in 0..5 {
        t = t + Millis::from_secs(30);
        c.run_until(t);
        let ids: Vec<WorkerId> = c.workers().iter().map(|w| w.id).collect();
        if !ids.is_empty() {
            let victim = ids[rng.below(ids.len() as u64) as usize];
            if c.fail_worker(victim) {
                crashes += 1;
            }
            assert_eq!(c.accounted_messages(), 60, "conservation after crash");
        }
    }
    assert!(crashes >= 3, "chaos actually happened ({crashes})");
    let makespan = c.run_to_completion(60, Millis::from_secs(4000));
    assert!(makespan.is_some(), "drained despite {crashes} crashes");
}

#[test]
fn autoscaler_replaces_failed_capacity() {
    let mut c = fast_cluster(3);
    // Enough work that the backlog is still deep when we crash a worker.
    burst(&mut c, 200, 20);
    c.run_until(Millis::from_secs(60));
    let before = c.workers().len();
    assert!(before >= 2);
    assert!(c.master.backlog_len() > 0, "still under pressure");
    let victim = c.workers()[before - 1].id;
    c.fail_worker(victim);
    // With backlog pressure the IRM must bring a replacement up.
    c.run_until(Millis::from_secs(110));
    assert!(
        c.workers().len() >= before,
        "capacity restored: {} -> {}",
        before,
        c.workers().len()
    );
}

#[test]
fn failing_unknown_worker_is_noop() {
    let mut c = fast_cluster(2);
    assert!(!c.fail_worker(WorkerId(99)));
}
