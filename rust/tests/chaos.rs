//! Failure injection: worker crashes mid-run must not lose messages, and
//! the IRM must restore capacity (the paper's reliability premise —
//! "recovery from failures" is table stakes for streaming frameworks).
//! The heterogeneous cases additionally pin the cost-aware contract:
//! crashes on an Xlarge/Large mix are answered in *reference units* of
//! capacity (not VM count), and the cloud's cost ledger stays monotone —
//! no negative spend, no double-billed cancelled boot — through arbitrary
//! crash/cancel churn. The spot cases add the provider-initiated failure
//! mode: preemption notices grace-drain workers, requeued containers are
//! never lost or double-hosted, reclaimed capacity is replaced in
//! reference units, and both the blended ledger and its spot share stay
//! monotone under preempt/cancel/crash churn. The zone cases inject the
//! *correlated* failure mode — a whole failure domain reclaiming every
//! spot VM it hosts in a single tick, repeatedly — and pin the same
//! invariants (conservation, exactly-once completion, monotone ledgers,
//! catalog-quantum replacement) plus the diversity contract: a spread
//! fleet rides through a zone kill with quiet-zone capacity intact.

use harmonicio::binpacking::Resource;
use harmonicio::cloud::{CloudConfig, Flavor, Zone};
use harmonicio::experiments::microscopy;
use harmonicio::irm::{FlavorOption, ResourceModel, SpotPolicy};
use harmonicio::sim::{Arrival, ClusterConfig, EventCore, SimCluster};
use harmonicio::types::{ImageName, Millis, WorkerId};
use harmonicio::util::rng::Rng;
use harmonicio::worker::WorkerConfig;

fn fast_cluster(quota: usize) -> SimCluster {
    let mut cfg: ClusterConfig = microscopy::cluster_config(99);
    cfg.cloud = CloudConfig {
        quota,
        boot_delay: Millis::from_secs(8),
        boot_jitter: Millis(2000),
        ..CloudConfig::default()
    };
    cfg.worker = WorkerConfig {
        container_boot: Millis(2000),
        container_boot_jitter: Millis(500),
        container_idle_timeout: Millis::from_secs(5),
        image_pull: Millis::ZERO,
        measure_noise_std: 0.0,
        ..WorkerConfig::default()
    };
    SimCluster::new(cfg)
}

fn burst(c: &mut SimCluster, n: usize, demand_s: u64) {
    for _ in 0..n {
        c.schedule_arrival(
            Millis(0),
            Arrival {
                image: ImageName::new("cellprofiler:3.1.9"),
                payload_bytes: 4 << 20,
                service_demand: Millis::from_secs(demand_s),
            },
        );
    }
}

#[test]
fn single_worker_crash_loses_nothing() {
    let mut c = fast_cluster(4);
    burst(&mut c, 80, 10);
    // Let the cluster ramp up and get busy.
    c.run_until(Millis::from_secs(60));
    assert!(!c.workers().is_empty());
    let victim = c.workers()[0].id;
    assert!(c.fail_worker(victim));
    assert_eq!(
        c.accounted_messages(),
        80,
        "crash must not lose messages"
    );
    // Everything still completes.
    let makespan = c.run_to_completion(80, Millis::from_secs(2000));
    assert!(makespan.is_some(), "all 80 messages completed after crash");
}

#[test]
fn repeated_random_crashes_still_drain() {
    let mut c = fast_cluster(4);
    burst(&mut c, 60, 8);
    let mut rng = Rng::seeded(7);
    let mut t = Millis::ZERO;
    let mut crashes = 0;
    // Crash a random worker every ~30 s of sim time, five times.
    for _ in 0..5 {
        t = t + Millis::from_secs(30);
        c.run_until(t);
        let ids: Vec<WorkerId> = c.workers().iter().map(|w| w.id).collect();
        if !ids.is_empty() {
            let victim = ids[rng.below(ids.len() as u64) as usize];
            if c.fail_worker(victim) {
                crashes += 1;
            }
            assert_eq!(c.accounted_messages(), 60, "conservation after crash");
        }
    }
    assert!(crashes >= 3, "chaos actually happened ({crashes})");
    let makespan = c.run_to_completion(60, Millis::from_secs(4000));
    assert!(makespan.is_some(), "drained despite {crashes} crashes");
}

#[test]
fn autoscaler_replaces_failed_capacity() {
    let mut c = fast_cluster(3);
    // Enough work that the backlog is still deep when we crash a worker.
    burst(&mut c, 200, 20);
    c.run_until(Millis::from_secs(60));
    let before = c.workers().len();
    assert!(before >= 2);
    assert!(c.master.backlog_len() > 0, "still under pressure");
    let victim = c.workers()[before - 1].id;
    c.fail_worker(victim);
    // With backlog pressure the IRM must bring a replacement up.
    c.run_until(Millis::from_secs(110));
    assert!(
        c.workers().len() >= before,
        "capacity restored: {} -> {}",
        before,
        c.workers().len()
    );
}

#[test]
fn failing_unknown_worker_is_noop() {
    let mut c = fast_cluster(2);
    assert!(!c.fail_worker(WorkerId(99)));
}

/// A cost-aware heterogeneous cluster: Xlarge/Large catalog + cycle,
/// vector packing, RAM-carrying workload.
fn hetero_cluster(quota: usize) -> SimCluster {
    SimCluster::new(hetero_cfg(quota))
}

fn hetero_cfg(quota: usize) -> ClusterConfig {
    let mut cfg: ClusterConfig = microscopy::cluster_config(7);
    cfg.cloud = CloudConfig {
        quota,
        boot_delay: Millis::from_secs(8),
        boot_jitter: Millis(2000),
        flavor_cycle: vec![Flavor::Xlarge, Flavor::Large],
        ..CloudConfig::default()
    };
    cfg.worker = WorkerConfig {
        container_boot: Millis(2000),
        container_boot_jitter: Millis(500),
        container_idle_timeout: Millis::from_secs(5),
        image_pull: Millis::ZERO,
        measure_noise_std: 0.0,
        ..WorkerConfig::default()
    };
    cfg.irm.resource_model = ResourceModel::Vector {
        new_vm_capacity: Flavor::Large.capacity(),
    };
    cfg.irm.image_resources =
        vec![harmonicio::workload::microscopy::resource_profile()];
    cfg.irm.flavor_catalog = vec![
        FlavorOption::nominal(Flavor::Xlarge, Millis::from_secs(8)),
        FlavorOption::nominal(Flavor::Large, Millis::from_secs(8)),
    ];
    cfg
}

#[test]
fn heterogeneous_crashes_replace_capacity_not_vm_count() {
    let mut c = hetero_cluster(8);
    // Enough work that the backlog stays deep well past both crash and
    // recovery (~500·30s·0.125 ref-seconds against ≤ 8 mixed VMs).
    burst(&mut c, 500, 30);
    c.run_until(Millis::from_secs(80));
    assert!(c.workers().len() >= 2, "mix ramped up");
    assert!(c.master.backlog_len() > 0, "still under pressure");
    let cap_before = c.total_capacity().get(Resource::Cpu);
    assert!(cap_before > 0.0);
    // Crash the two newest workers (on the Xlarge/Large cycle that is a
    // mixed-flavor loss), then let the scaler respond.
    let victims: Vec<WorkerId> = {
        let ws = c.workers();
        ws[ws.len().saturating_sub(2)..].iter().map(|w| w.id).collect()
    };
    for v in victims {
        assert!(c.fail_worker(v));
    }
    assert_eq!(c.accounted_messages(), 500, "conservation through crashes");
    c.run_until(Millis::from_secs(160));
    assert!(c.master.backlog_len() > 0, "pressure sustained through recovery");
    let cap_after = c.total_capacity().get(Resource::Cpu);
    // The contract is reference units, not VM count: under sustained
    // pressure the replacement capacity must reach the pre-crash level,
    // whatever flavor mix delivers it.
    assert!(
        cap_after >= cap_before - 1e-9,
        "capacity replaced: {cap_before} -> {cap_after} reference units"
    );
    // The replacement is capacity-shaped, not count-shaped: the total is
    // a sum of catalog-flavor capacities (0.5 or 1.0 reference CPUs), so
    // doubling it must land on an integer — a smoke check that no
    // non-catalog capacity snuck in.
    let doubled = cap_after * 2.0;
    assert!(
        (doubled - doubled.round()).abs() < 1e-6,
        "capacity {cap_after} is not a sum of Xlarge/Large units"
    );
}

/// The spot variant of [`hetero_cluster`]: the whole fleet may be
/// bought spot, under an aggressive preemption hazard (mean spot VM
/// lifetime `3600/hazard_per_hour` seconds) so provider reclaims
/// actually churn the run.
fn spot_cluster(quota: usize, hazard_per_hour: f64) -> SimCluster {
    let mut cfg = hetero_cfg(quota);
    let boot = Millis::from_secs(8);
    cfg.cloud.spot_hazard = vec![
        (Flavor::Small, hazard_per_hour),
        (Flavor::Large, hazard_per_hour),
        (Flavor::Xlarge, hazard_per_hour),
    ];
    cfg.cloud.preemption_notice = Millis::from_secs(10);
    cfg.irm.flavor_catalog = vec![
        FlavorOption {
            spot_hazard_per_hour: hazard_per_hour,
            ..FlavorOption::nominal_spot(Flavor::Xlarge, boot)
        },
        FlavorOption {
            spot_hazard_per_hour: hazard_per_hour,
            ..FlavorOption::nominal_spot(Flavor::Large, boot)
        },
    ];
    cfg.irm.spot_policy = SpotPolicy {
        max_spot_fraction: 1.0,
        rework_penalty_usd: 0.001,
        ..SpotPolicy::default()
    };
    SimCluster::new(cfg)
}

/// The zone-aware variant of [`spot_cluster`]: an all-spot fleet with
/// three failure domains and all *correlated* hazard concentrated in
/// zone 0 (individual spot hazard zero, isolating the correlated path).
/// `zones = 0` leaves spreading off — every spot VM lands in the hot
/// default zone 0 — while `zones = 3` spreads each planning round with
/// at most `max_zone_fraction` of its spot units in any one zone.
fn zoned_cluster(
    quota: usize,
    zone0_per_hour: f64,
    zones: usize,
    max_zone_fraction: f64,
) -> SimCluster {
    let mut cfg = hetero_cfg(quota);
    let boot = Millis::from_secs(8);
    cfg.cloud.zone_hazard = vec![zone0_per_hour, 0.0, 0.0];
    cfg.cloud.preemption_notice = Millis::from_secs(10);
    cfg.irm.flavor_catalog = vec![
        FlavorOption::nominal_spot(Flavor::Xlarge, boot),
        FlavorOption::nominal_spot(Flavor::Large, boot),
    ];
    cfg.irm.spot_policy = SpotPolicy {
        max_spot_fraction: 1.0,
        rework_penalty_usd: 0.001,
        zones,
        max_zone_fraction,
    };
    SimCluster::new(cfg)
}

#[test]
fn spot_preemptions_never_lose_or_double_host_messages() {
    // Mean spot lifetime two minutes on an all-spot fleet: the
    // notice → grace-drain → requeue → reclaim → replace loop runs many
    // times. At every checkpoint each message must be exactly one of
    // completed / backlogged / in-flight (never lost, never cloned into
    // two PEs), and the whole batch must still drain.
    let mut c = spot_cluster(8, 30.0);
    burst(&mut c, 150, 12);
    let mut t = Millis::ZERO;
    for _ in 0..20 {
        t = t + Millis::from_secs(15);
        c.run_until(t);
        assert_eq!(
            c.accounted_messages(),
            150,
            "conservation violated under preemption churn at {t}"
        );
    }
    assert!(
        c.cloud.preemptions >= 1,
        "a two-minute mean lifetime over 300 s must reclaim something"
    );
    let makespan = c.run_to_completion(150, Millis::from_secs(6000));
    assert!(makespan.is_some(), "drained despite spot churn");
    assert_eq!(c.completions.len(), 150, "every message completed exactly once");
}

#[test]
fn preempted_capacity_is_replaced_in_reference_units() {
    // Under sustained backlog pressure, whatever the provider reclaims
    // must come back as *capacity* (reference units), not as a VM
    // count — and only in catalog-flavor quanta.
    // ~800·30s·0.125 = 3000 ref-seconds against ≤ 8 mixed VMs: the
    // backlog outlasts the whole churn window by a wide margin.
    let mut c = spot_cluster(8, 30.0);
    burst(&mut c, 800, 30);
    c.run_until(Millis::from_secs(80));
    assert!(c.master.backlog_len() > 0, "still under pressure");
    let cap_early = c.total_capacity().get(Resource::Cpu);
    assert!(cap_early > 0.0);
    // Let preemptions and replacements churn for a while.
    c.run_until(Millis::from_secs(380));
    assert!(c.master.backlog_len() > 0, "pressure sustained through churn");
    assert!(c.cloud.preemptions >= 1, "churn actually happened");
    // Capacity is a sum of catalog-flavor capacities (0.5 / 1.0
    // reference CPUs): doubling it must land on an integer.
    let cap_late = c.total_capacity().get(Resource::Cpu);
    let doubled = cap_late * 2.0;
    assert!(
        (doubled - doubled.round()).abs() < 1e-6,
        "capacity {cap_late} is not a sum of Xlarge/Large units"
    );
    // The autoscaler kept the fleet useful: messages keep completing
    // through the churn window (capacity was genuinely replaced, not
    // just counted).
    assert!(
        !c.completions.is_empty(),
        "work progressed through preemption churn"
    );
    assert_eq!(c.accounted_messages(), 800, "conservation held throughout");
}

#[test]
fn cost_ledger_monotone_under_preempt_cancel_crash_churn() {
    // All three failure modes interleaved — provider reclaims (spot),
    // operator crashes, and cost-valve boot cancellations — must keep
    // both the blended ledger and its spot share monotone, and the spot
    // share must never exceed the total.
    let mut c = spot_cluster(6, 20.0);
    burst(&mut c, 120, 12);
    let mut rng = Rng::seeded(13);
    let mut last_cost = 0.0_f64;
    let mut last_spot = 0.0_f64;
    let mut t = Millis::ZERO;
    for round in 0..16 {
        t = t + Millis::from_secs(15);
        c.run_until(t);
        let cost = c.cloud.cost_usd();
        let spot = c.cloud.spot_cost_usd();
        assert!(cost >= 0.0 && spot >= 0.0);
        assert!(
            cost >= last_cost - 1e-12,
            "ledger regressed at round {round}: {last_cost} -> {cost}"
        );
        assert!(
            spot >= last_spot - 1e-12,
            "spot ledger regressed at round {round}: {last_spot} -> {spot}"
        );
        assert!(spot <= cost + 1e-9, "spot share exceeds the blended total");
        last_cost = cost;
        last_spot = spot;
        match round % 3 {
            0 => {
                let ids: Vec<WorkerId> = c.workers().iter().map(|w| w.id).collect();
                if !ids.is_empty() {
                    c.fail_worker(ids[rng.below(ids.len() as u64) as usize]);
                }
            }
            1 => {
                c.cloud.cancel_costliest_booting(c.now());
            }
            _ => {} // let scheduled preemptions do the damage
        }
        assert_eq!(c.accounted_messages(), 120, "conservation after chaos round");
    }
    assert!(last_cost > 0.0, "the run was billed at all");
    let makespan = c.run_to_completion(120, Millis::from_secs(6000));
    assert!(makespan.is_some(), "drained despite preempt/cancel/crash churn");
    assert!(c.cloud.cost_usd() >= last_cost);
}

#[test]
fn cost_ledger_monotone_through_crash_and_cancel_churn() {
    let mut c = hetero_cluster(6);
    burst(&mut c, 120, 12);
    let mut rng = Rng::seeded(11);
    let mut last_cost = 0.0_f64;
    let mut t = Millis::ZERO;
    for round in 0..12 {
        t = t + Millis::from_secs(15);
        c.run_until(t);
        let cost = c.cloud.cost_usd();
        assert!(cost >= 0.0, "spend can never be negative");
        assert!(
            cost >= last_cost - 1e-12,
            "ledger went backwards at round {round}: {last_cost} -> {cost}"
        );
        last_cost = cost;
        // Alternate chaos: crash a random live worker, or cancel the
        // costliest in-flight boot directly (double-billing bait — the
        // ledger must keep the cancelled VM billed exactly once).
        if round % 2 == 0 {
            let ids: Vec<WorkerId> = c.workers().iter().map(|w| w.id).collect();
            if !ids.is_empty() {
                c.fail_worker(ids[rng.below(ids.len() as u64) as usize]);
                assert_eq!(c.accounted_messages(), 120, "conservation after crash");
            }
        } else {
            // The last tick ran at `t`, so cancelling at `t` has no
            // unbilled partial interval: the ledger must not move (the
            // double-billing bait — and with sub-tick billing, the
            // cancellation instant is billed exactly once, here as zero).
            let before = c.cloud.cost_usd();
            c.cloud.cancel_costliest_booting(c.now());
            assert_eq!(
                c.cloud.cost_usd(),
                before,
                "cancelling at the already-billed instant must not touch the ledger"
            );
        }
    }
    assert!(last_cost > 0.0, "the run was billed at all");
    // Everything still drains despite the churn.
    let makespan = c.run_to_completion(120, Millis::from_secs(4000));
    assert!(makespan.is_some(), "drained despite crash/cancel churn");
    assert!(c.cloud.cost_usd() >= last_cost);
}

#[test]
fn zone_kill_reclaims_fleet_conserves_messages_and_ledger() {
    // Naive single-zone placement: every spot VM sits in the hot zone,
    // so each scheduled zone failure reclaims the whole spot fleet in
    // one tick. The zone-failure schedule is drawn at construction, so
    // the test walks the actual instants instead of guessing times.
    let mut c = zoned_cluster(8, 30.0, 0, 0.0);
    burst(&mut c, 150, 12);
    let schedule: Vec<Millis> = c.cloud.zone_failures(Zone(0)).to_vec();
    assert!(!schedule.is_empty(), "the hot zone drew a failure schedule");
    let mut last_cost = 0.0_f64;
    let mut last_spot = 0.0_f64;
    for &at in schedule.iter().take(4) {
        c.run_until(at + Millis::from_secs(15));
        assert_eq!(
            c.accounted_messages(),
            150,
            "conservation after the zone kill at {at}"
        );
        let (cost, spot) = (c.cloud.cost_usd(), c.cloud.spot_cost_usd());
        assert!(
            cost >= last_cost - 1e-12 && spot >= last_spot - 1e-12,
            "ledgers monotone through the zone kill at {at}"
        );
        assert!(spot <= cost + 1e-9, "spot share exceeds the blended total");
        last_cost = cost;
        last_spot = spot;
    }
    assert!(
        c.cloud.zone_preemptions >= 1,
        "a zone kill actually reclaimed spot VMs"
    );
    let makespan = c.run_to_completion(150, Millis::from_secs(6000));
    assert!(makespan.is_some(), "drained despite repeated whole-zone kills");
    assert_eq!(c.completions.len(), 150, "every message completed exactly once");
}

#[test]
fn diverse_spread_limits_zone_blast_radius() {
    // Same hot zone, but the planner spreads: at most 40% of each
    // round's spot units in any one zone, so a zone kill can never take
    // the whole fleet — quiet-zone capacity must ride straight through
    // the reclaim tick, and replacements stay in catalog quanta.
    let mut c = zoned_cluster(8, 30.0, 3, 0.4);
    burst(&mut c, 800, 30);
    let schedule: Vec<Millis> = c.cloud.zone_failures(Zone(0)).to_vec();
    assert!(!schedule.is_empty(), "the hot zone drew a failure schedule");
    c.run_until(Millis::from_secs(80));
    assert!(c.master.backlog_len() > 0, "still under pressure");
    // Walk the first few kills that land after the fleet ramped.
    let ramped = Millis::from_secs(80);
    for &at in schedule.iter().filter(|&&at| at >= ramped).take(3) {
        c.run_until(at + Millis(100));
        assert!(
            c.total_capacity().get(Resource::Cpu) > 0.0,
            "diversity keeps quiet-zone capacity through the kill at {at}"
        );
        assert_eq!(c.accounted_messages(), 800, "conservation after zone kill");
        let doubled = c.total_capacity().get(Resource::Cpu) * 2.0;
        assert!(
            (doubled - doubled.round()).abs() < 1e-6,
            "capacity is not a sum of Xlarge/Large units after the kill"
        );
    }
    assert!(
        c.cloud.zone_preemptions >= 1,
        "zone kills actually reclaimed spread spot VMs"
    );
    assert!(
        !c.completions.is_empty(),
        "work progressed through correlated churn"
    );
}

#[test]
fn deep_repeated_zone_kills_conserve_everything() {
    // Deep chaos at an aggressive cadence (mean one whole-zone kill per
    // minute on a spread fleet). Scaled by TESTKIT_CASES like the
    // property suites, so `ci_check.sh --deep` cranks the churn window.
    let cases: usize = std::env::var("TESTKIT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(600);
    let rounds = (cases / 100).max(6);
    let mut c = zoned_cluster(6, 60.0, 3, 0.5);
    burst(&mut c, 120, 10);
    let mut t = Millis::ZERO;
    let mut last_cost = 0.0_f64;
    for round in 0..rounds {
        t = t + Millis::from_secs(20);
        c.run_until(t);
        assert_eq!(
            c.accounted_messages(),
            120,
            "conservation at zone-churn round {round}"
        );
        let cost = c.cloud.cost_usd();
        assert!(
            cost >= last_cost - 1e-12,
            "ledger regressed at zone-churn round {round}: {last_cost} -> {cost}"
        );
        last_cost = cost;
    }
    let makespan = c.run_to_completion(120, Millis::from_secs(6000));
    assert!(makespan.is_some(), "drained despite repeated zone kills");
    assert_eq!(c.completions.len(), 120, "every message completed exactly once");
}

/// Determinism pin for the wheel event core under correlated chaos: a
/// whole-zone spot reclaim fires at an instant drawn at construction,
/// which lands on a wheel-scheduled tick boundary between worker
/// deadlines (draining workers, requeue bursts and replacement boots
/// all cross the wheel's skip paths at once). The wheel run must replay
/// the legacy scan byte for byte — recorder CSV at the kill tick and at
/// the end, completion log, both ledgers, rework — through the episode.
#[test]
fn zone_kill_on_wheel_tick_boundary_matches_scan_core() {
    let run = |core: EventCore| {
        let mut c = zoned_cluster(8, 30.0, 3, 0.4);
        c.cfg.event_core = core;
        burst(&mut c, 150, 12);
        let schedule: Vec<Millis> = c.cloud.zone_failures(Zone(0)).to_vec();
        assert!(!schedule.is_empty(), "the hot zone drew a failure schedule");
        let first = schedule[0];
        // Stop exactly one tick past the kill instant, snapshot, then
        // let the recovery (requeues, replacements) play out.
        c.run_until(first + Millis(100));
        let csv_at_kill = c.recorder.to_csv();
        c.run_until(first + Millis::from_secs(120));
        (
            csv_at_kill,
            c.recorder.to_csv(),
            format!("{:?}", c.completions),
            format!("{:.12}", c.cloud.cost_usd()),
            format!("{:.12}", c.cloud.spot_cost_usd()),
            c.cloud.zone_preemptions,
            c.rework_ms,
            c.accounted_messages(),
        )
    };
    let scan = run(EventCore::Scan);
    let wheel = run(EventCore::Wheel);
    assert_eq!(
        scan.0, wheel.0,
        "recorder CSV must be byte-identical at the kill tick"
    );
    assert_eq!(scan, wheel, "the whole episode must match the scan oracle");
}

/// Sharded scheduling plane under total shard-slice loss: every worker
/// owned by one IRM shard crashes in the same instant. The other
/// shards' slices must ride through untouched, the coordinator must
/// re-assign replacement workers and re-route the dead slice's requeued
/// work, and the global invariants — message conservation and
/// exactly-once completion — must hold through the whole episode.
#[test]
fn sharded_whole_slice_crash_conserves_and_completes_exactly_once() {
    let mut cfg: ClusterConfig = microscopy::cluster_config(99);
    cfg.cloud = CloudConfig {
        quota: 6,
        boot_delay: Millis::from_secs(8),
        boot_jitter: Millis(2000),
        ..CloudConfig::default()
    };
    cfg.worker = WorkerConfig {
        container_boot: Millis(2000),
        container_boot_jitter: Millis(500),
        container_idle_timeout: Millis::from_secs(5),
        image_pull: Millis::ZERO,
        measure_noise_std: 0.0,
        ..WorkerConfig::default()
    };
    cfg.irm.sharding.shards = 2;
    let mut c = SimCluster::new(cfg);
    // Four distinct streams so the hash ring gives every shard work.
    let total = 120;
    for img in ["stream-a", "stream-b", "stream-c", "stream-d"] {
        for _ in 0..30 {
            c.schedule_arrival(
                Millis(0),
                Arrival {
                    image: ImageName::new(img),
                    payload_bytes: 4 << 20,
                    service_demand: Millis::from_secs(8),
                },
            );
        }
    }
    c.run_until(Millis::from_secs(60));
    assert!(c.workers().len() >= 2, "fleet ramped up");
    // Kill shard 0's whole worker slice in one tick (fall back to the
    // entire fleet if assignment happened to leave shard 0 empty — an
    // even harder episode).
    let victims: Vec<WorkerId> = {
        let sharded = c.irm.sharded().expect("sharded mode is on");
        let slice: Vec<WorkerId> = c
            .workers()
            .iter()
            .map(|w| w.id)
            .filter(|id| sharded.shard_of_worker(*id) == Some(0))
            .collect();
        if slice.is_empty() {
            c.workers().iter().map(|w| w.id).collect()
        } else {
            slice
        }
    };
    assert!(!victims.is_empty());
    for id in victims {
        assert!(c.fail_worker(id));
        assert_eq!(
            c.accounted_messages(),
            total,
            "conservation through the slice crash"
        );
    }
    let makespan = c.run_to_completion(total, Millis::from_secs(4000));
    assert!(makespan.is_some(), "drained after losing a whole shard slice");
    assert_eq!(c.completions.len(), total, "every message completed exactly once");
}
