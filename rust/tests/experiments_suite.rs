//! The whole figure suite as an integration test: every experiment's shape
//! checks must pass at the default seed (the same gate `repro experiment
//! all` enforces), and the A4/A5 headline metrics are pinned per seed by
//! a golden snapshot so planner refactors can't silently shift results.

#[test]
fn all_figures_reproduce_with_passing_checks() {
    let out = std::env::temp_dir().join("hio_experiments_suite");
    std::fs::create_dir_all(&out).unwrap();
    let reports =
        harmonicio::experiments::run("all", out.to_str().unwrap(), 42).expect("suite runs");
    assert_eq!(reports.len(), 18, "all 18 experiments ran");
    let mut failed = Vec::new();
    for r in &reports {
        for c in &r.checks {
            if !c.passed {
                failed.push(format!("{} :: {} ({})", r.title, c.name, c.detail));
            }
        }
    }
    assert!(failed.is_empty(), "failing checks:\n{}", failed.join("\n"));

    // Every figure CSV must exist and be non-trivial.
    for fig in [
        "fig3.csv",
        "fig4.csv",
        "fig5.csv",
        "fig7.csv",
        "fig8.csv",
        "fig9.csv",
        "fig10.csv",
        "headline.csv",
        "warmup.csv",
        "ablation_packer.csv",
        "ablation_buffer.csv",
        "ablation_profiler.csv",
        "ablation_multidim.csv",
        "ablation_cost.csv",
        "ablation_liveprofile.csv",
        "ablation_spot.csv",
        "ablation_zonefail.csv",
        "ablation_shard.csv",
    ] {
        let path = out.join(fig);
        let meta = std::fs::metadata(&path).unwrap_or_else(|_| panic!("{fig} missing"));
        assert!(meta.len() > 40, "{fig} too small ({} bytes)", meta.len());
    }
}

#[test]
fn figures_are_deterministic_per_seed() {
    let out_a = std::env::temp_dir().join("hio_exp_det_a");
    let out_b = std::env::temp_dir().join("hio_exp_det_b");
    for out in [&out_a, &out_b] {
        std::fs::create_dir_all(out).unwrap();
        harmonicio::experiments::run("fig5", out.to_str().unwrap(), 7).unwrap();
    }
    let a = std::fs::read_to_string(out_a.join("fig5.csv")).unwrap();
    let b = std::fs::read_to_string(out_b.join("fig5.csv")).unwrap();
    assert_eq!(a, b, "same seed → identical figure data");
}

/// Golden regression pin for the A4–A8 headline metrics at seed
/// 42: the full metric CSVs (overcommit_pp, cost_usd, spot spend and
/// preemption counts, zone preemptions, rework seconds, deadline
/// misses, makespans, peak workers,
/// live-profile convergence) are snapshotted
/// under `rust/tests/golden/` and compared byte-for-byte — the
/// experiments are deterministic per seed, so any diff is a behavior
/// change in the packing/planning/profiling stack, not noise. The
/// scalar-CPU (`ResourceModel::CpuOnly`) arms inside these experiments
/// double as the regression pin that the vector-telemetry refactor left
/// CPU-only behavior untouched.
///
/// Bootstrap/refresh protocol: when a golden file is missing (first run
/// on a fresh checkout) it is written and the test passes with a notice —
/// **commit the generated file** so later refactors compare against it.
/// To intentionally re-baseline after a deliberate planner change, run
/// with `GOLDEN_UPDATE=1` and commit the diff; a mismatch without that
/// env var is a regression failure. Independently of the snapshot, the
/// test always re-runs each experiment a second time in-process and
/// requires byte-identical CSVs, so per-seed determinism is enforced
/// even before a golden is committed.
#[test]
fn golden_ablation_metrics_pinned_per_seed() {
    let out_a = std::env::temp_dir().join("hio_golden_ablations_a");
    let out_b = std::env::temp_dir().join("hio_golden_ablations_b");
    for out in [&out_a, &out_b] {
        std::fs::create_dir_all(out).unwrap();
        harmonicio::experiments::run("ablation-multidim", out.to_str().unwrap(), 42).unwrap();
        harmonicio::experiments::run("ablation-cost", out.to_str().unwrap(), 42).unwrap();
        harmonicio::experiments::run("ablation-liveprofile", out.to_str().unwrap(), 42).unwrap();
        harmonicio::experiments::run("ablation-spot", out.to_str().unwrap(), 42).unwrap();
        harmonicio::experiments::run("ablation-zonefail", out.to_str().unwrap(), 42).unwrap();
        harmonicio::experiments::run("ablation-shard", out.to_str().unwrap(), 42).unwrap();
    }

    let golden_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    std::fs::create_dir_all(&golden_dir).unwrap();
    let update = std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false);
    for csv in [
        "ablation_multidim.csv",
        "ablation_cost.csv",
        "ablation_liveprofile.csv",
        "ablation_spot.csv",
        "ablation_zonefail.csv",
        "ablation_shard.csv",
    ] {
        let produced = std::fs::read_to_string(out_a.join(csv)).unwrap();
        let rerun = std::fs::read_to_string(out_b.join(csv)).unwrap();
        assert_eq!(
            produced, rerun,
            "{csv} not deterministic at seed 42 — a golden pin is meaningless"
        );
        let golden_path = golden_dir.join(format!("{csv}.seed42.golden"));
        if update || !golden_path.exists() {
            std::fs::write(&golden_path, &produced).unwrap();
            eprintln!(
                "golden: wrote {} — commit it to pin these metrics",
                golden_path.display()
            );
            continue;
        }
        let golden = std::fs::read_to_string(&golden_path).unwrap();
        assert_eq!(
            produced, golden,
            "{csv} diverged from its seed-42 golden pin \
             ({}). If the change is intentional, re-baseline with \
             GOLDEN_UPDATE=1 and commit the new golden.",
            golden_path.display()
        );
    }
}

/// Vector E9-style warm-up regression: the paper's warm-up observation
/// (run 1 is slightly worse until the profile converges) must hold per
/// dimension. Run 1 starts from a deliberately wrong RAM prior and must
/// converge within 10% of the truth by its end; run 2 — carrying the
/// profile, like the paper's 10-run protocol — must start already
/// converged and show zero actual RAM overcommit from its very first
/// sample window.
#[test]
fn vector_warmup_profile_converges_and_carries_over() {
    use harmonicio::binpacking::{Resource, ResourceVec};
    use harmonicio::cloud::Flavor;
    use harmonicio::irm::ResourceModel;
    use harmonicio::sim::SimCluster;
    use harmonicio::types::Millis;
    use harmonicio::workload::{microscopy, MicroscopyConfig, MicroscopyTrace};

    let (image, truth) = microscopy::resource_profile();
    let true_ram = truth.get(Resource::Ram);
    let dataset = MicroscopyTrace::new(MicroscopyConfig {
        n_images: 120,
        ..MicroscopyConfig::default()
    });
    let mut carried_profiler = None;
    let mut carried_cache = None;
    let mut estimates = Vec::new();
    let mut overcommits = Vec::new();
    for run_idx in 0..2u64 {
        let mut cfg = harmonicio::experiments::microscopy::cluster_config(17 ^ (run_idx << 8));
        cfg.cloud.flavor_cycle = vec![Flavor::Xlarge, Flavor::Large];
        cfg.irm.resource_model = ResourceModel::Vector {
            new_vm_capacity: Flavor::Large.capacity(),
        };
        // Wrong cold-start prior; the workload really pins `truth`.
        cfg.irm.image_resources = vec![(image.clone(), ResourceVec::new(0.0, 0.08, 0.01))];
        cfg.image_resource_usage = vec![(image.clone(), truth)];
        let trace = dataset.run_trace(17 ^ run_idx);
        let mut cluster = SimCluster::new(cfg);
        if let Some(p) = carried_profiler.take() {
            cluster.irm.set_profiler(p);
        }
        if let Some(c) = carried_cache.take() {
            cluster.pulled_images = c;
        }
        trace.schedule_into(&mut cluster);
        cluster
            .run_to_completion(trace.len(), Millis::from_secs(4000))
            .expect("batch completes");
        estimates.push(cluster.irm.resource_estimate(&image).get(Resource::Ram));
        overcommits.push(
            cluster
                .recorder
                .get("ram.overcommit_actual_pp")
                .map(|s| s.max())
                .unwrap_or(0.0),
        );
        carried_profiler = Some(cluster.irm.profiler().clone());
        carried_cache = Some(cluster.pulled_images.clone());
    }
    // Run 1 converged by its end (the E9 warm-up window is bounded).
    assert!(
        (estimates[0] - true_ram).abs() <= 0.1 * true_ram,
        "run 1 estimate {} should be within 10% of {true_ram}",
        estimates[0]
    );
    // Run 2 starts warm: still converged, and never overcommits real RAM
    // at any point (run 1 may, during its warm-up window — that is the
    // warm-up effect itself).
    assert!(
        (estimates[1] - true_ram).abs() <= 0.1 * true_ram,
        "run 2 estimate {} drifted",
        estimates[1]
    );
    assert!(
        overcommits[1] <= 1e-6,
        "a profile-warm run must never overcommit real RAM, got {} pp",
        overcommits[1]
    );
}
