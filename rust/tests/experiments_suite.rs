//! The whole figure suite as an integration test: every experiment's shape
//! checks must pass at the default seed (the same gate `repro experiment
//! all` enforces).

#[test]
fn all_figures_reproduce_with_passing_checks() {
    let out = std::env::temp_dir().join("hio_experiments_suite");
    std::fs::create_dir_all(&out).unwrap();
    let reports =
        harmonicio::experiments::run("all", out.to_str().unwrap(), 42).expect("suite runs");
    assert_eq!(reports.len(), 13, "all 13 experiments ran");
    let mut failed = Vec::new();
    for r in &reports {
        for c in &r.checks {
            if !c.passed {
                failed.push(format!("{} :: {} ({})", r.title, c.name, c.detail));
            }
        }
    }
    assert!(failed.is_empty(), "failing checks:\n{}", failed.join("\n"));

    // Every figure CSV must exist and be non-trivial.
    for fig in [
        "fig3.csv",
        "fig4.csv",
        "fig5.csv",
        "fig7.csv",
        "fig8.csv",
        "fig9.csv",
        "fig10.csv",
        "headline.csv",
        "warmup.csv",
        "ablation_packer.csv",
        "ablation_buffer.csv",
        "ablation_profiler.csv",
        "ablation_multidim.csv",
    ] {
        let path = out.join(fig);
        let meta = std::fs::metadata(&path).unwrap_or_else(|_| panic!("{fig} missing"));
        assert!(meta.len() > 40, "{fig} too small ({} bytes)", meta.len());
    }
}

#[test]
fn figures_are_deterministic_per_seed() {
    let out_a = std::env::temp_dir().join("hio_exp_det_a");
    let out_b = std::env::temp_dir().join("hio_exp_det_b");
    for out in [&out_a, &out_b] {
        std::fs::create_dir_all(out).unwrap();
        harmonicio::experiments::run("fig5", out.to_str().unwrap(), 7).unwrap();
    }
    let a = std::fs::read_to_string(out_a.join("fig5.csv")).unwrap();
    let b = std::fs::read_to_string(out_b.join("fig5.csv")).unwrap();
    assert_eq!(a, b, "same seed → identical figure data");
}
