//! Full P2P distributed-mode integration (the paper's Fig 1 flow):
//! connector asks the master for an endpoint → sends the image *directly*
//! to the worker (P2P, the master never touches the pixels) → when every
//! worker is busy, the connector falls back to the master backlog, whose
//! dispatcher drains with priority.

use harmonicio::master::service::MasterService;
use harmonicio::transport::call;
use harmonicio::util::json::Json;
use harmonicio::worker::agent::WorkerAgent;
use harmonicio::workload::ImageGen;

fn pixels_json(pixels: &[f32]) -> Json {
    Json::arr(pixels.iter().map(|p| Json::num(*p as f64)))
}

#[test]
fn p2p_routing_with_backlog_fallback() {
    // Two remote workers, one master — all separate TCP endpoints.
    let w1 = match WorkerAgent::start("127.0.0.1:0", "artifacts", 1) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("skipping p2p test: {e:#}");
            return;
        }
    };
    let w2 = WorkerAgent::start("127.0.0.1:0", "artifacts", 1).unwrap();
    let master = MasterService::start("127.0.0.1:0").unwrap();

    // Workers register with the master (the paper's worker → master
    // reporting channel).
    for w in [&w1, &w2] {
        let resp = call(
            master.addr(),
            &Json::obj([
                ("type", Json::str("register")),
                ("addr", Json::str(w.addr().to_string())),
            ]),
        )
        .unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    }

    let mut gen = ImageGen::new(3, 128);
    let mut p2p_done = 0u64;
    let mut queued = 0u64;
    let n = 10;
    for i in 0..n {
        let planted = 10 + (i % 3) * 5;
        let img = gen.generate(planted as usize);
        // 1. Endpoint query.
        let ep = call(
            master.addr(),
            &Json::obj([("type", Json::str("endpoint"))]),
        )
        .unwrap();
        let direct = ep.get("queued").and_then(|v| v.as_bool()) == Some(false);
        if direct {
            // 2a. P2P: send the payload straight to the worker.
            let worker_addr = ep.get("worker").unwrap().as_str().unwrap().to_string();
            let resp = call(
                worker_addr.as_str(),
                &Json::obj([
                    ("type", Json::str("analyze")),
                    ("pixels", pixels_json(&img)),
                ]),
            )
            .unwrap();
            if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                let count = resp.get("features").unwrap().as_arr().unwrap()[0]
                    .as_f64()
                    .unwrap();
                assert!(count > 0.0, "counted something");
                p2p_done += 1;
                continue;
            }
            // Worker said busy → fall through to the backlog.
        }
        // 2b. Backlog fallback.
        let resp = call(
            master.addr(),
            &Json::obj([
                ("type", Json::str("enqueue")),
                ("pixels", pixels_json(&img)),
            ]),
        )
        .unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        queued += 1;
    }

    // Wait for the dispatcher to drain the backlog.
    let t0 = std::time::Instant::now();
    while master.backlog_len() > 0 || master.dispatched() < queued {
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(180),
            "backlog stuck: {} left, {} dispatched of {queued}",
            master.backlog_len(),
            master.dispatched()
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // Every message processed exactly once, across both channels.
    let total = w1.completed() + w2.completed();
    assert_eq!(total, n as u64, "p2p {p2p_done} + queued {queued}");
    assert_eq!(p2p_done + queued, n as u64);

    // Queued results are retrievable by the client.
    let drained = call(
        master.addr(),
        &Json::obj([("type", Json::str("drain_results"))]),
    )
    .unwrap();
    assert_eq!(
        drained.get("results").unwrap().as_arr().unwrap().len() as u64,
        queued
    );

    master.shutdown();
    w1.shutdown();
    w2.shutdown();
}
