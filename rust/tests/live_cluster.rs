//! Live-cluster end-to-end: real PE threads, real PJRT executions, the
//! master's route/backlog logic, and queue-pressure PE auto-scaling.

use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::workload::ImageGen;

fn cluster(max_pes: usize, initial: usize) -> Option<LiveCluster> {
    match LiveCluster::new(
        "artifacts",
        LiveConfig {
            max_pes,
            initial_pes: initial,
            scale_up_backlog_per_pe: 2,
        },
    ) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping live cluster test: {e:#}");
            None
        }
    }
}

#[test]
fn processes_a_plate_end_to_end() {
    let Some(mut c) = cluster(2, 2) else { return };
    let mut gen = ImageGen::new(0, 128);
    let plate = gen.plate(6);
    for (_, px) in &plate {
        c.stream(px.clone());
    }
    c.drain_until(6, std::time::Duration::from_secs(300)).unwrap();
    assert_eq!(c.results.len(), 6);
    // Every job measured wall + cpu time and produced sane features.
    for r in &c.results {
        let planted = plate[r.id.0 as usize].0 as f32;
        assert!(r.features[0] >= planted * 0.5 - 1.0, "count {}", r.features[0]);
        assert!(r.wall.as_nanos() > 0);
        assert!(r.cpu.as_nanos() > 0, "thread CPU time measured");
        assert!(r.latency >= r.wall);
    }
}

#[test]
fn backlog_pressure_scales_up_pes() {
    let Some(mut c) = cluster(3, 1) else { return };
    assert_eq!(c.pe_count(), 1);
    let mut gen = ImageGen::new(1, 128);
    for (_, px) in gen.plate(9) {
        c.stream(px);
    }
    c.drain_until(9, std::time::Duration::from_secs(300)).unwrap();
    assert!(
        c.stats.pes_peak > 1,
        "queue pressure should add PEs (peak {})",
        c.stats.pes_peak
    );
    assert!(c.pe_count() <= 3, "max_pes respected");
}

#[test]
fn results_complete_exactly_once() {
    let Some(mut c) = cluster(2, 2) else { return };
    let mut gen = ImageGen::new(2, 128);
    let n = 8;
    for (_, px) in gen.plate(n) {
        c.stream(px);
    }
    c.drain_until(n as u64, std::time::Duration::from_secs(300))
        .unwrap();
    let mut ids: Vec<u64> = c.results.iter().map(|r| r.id.0).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "every message completed exactly once");
    assert_eq!(c.stats.submitted, n as u64);
    assert_eq!(c.stats.completed, n as u64);
}
