//! PJRT runtime integration: the HLO-text → compile → execute contract the
//! whole request path relies on (the rust twin of python's kernel tests).
//!
//! Requires `make artifacts` (skips gracefully when missing so plain
//! `cargo test` works before the python toolchain ran).

use harmonicio::runtime::Runtime;
use harmonicio::workload::ImageGen;

fn runtime() -> Option<Runtime> {
    match Runtime::load_dir("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime integration: {e:#}");
            None
        }
    }
}

#[test]
fn loads_all_manifest_artifacts() {
    let Some(rt) = runtime() else { return };
    let names = rt.names();
    assert!(names.iter().any(|n| n.starts_with("nuclei")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("busy")), "{names:?}");
    let platform = rt.platform().to_lowercase();
    assert!(platform == "cpu" || platform == "host", "{platform}");
}

#[test]
fn nuclei_counts_track_planted_density() {
    let Some(rt) = runtime() else { return };
    for (seed, planted) in [(1u64, 8usize), (2, 20), (3, 45)] {
        let mut gen = ImageGen::new(seed, 128);
        let img = gen.generate(planted);
        let [count, area, mean_fg, thr] = rt.analyze_image(&img).unwrap();
        assert!(
            count >= planted as f32 * 0.5 && count <= planted as f32 * 1.5 + 2.0,
            "planted {planted}, counted {count}"
        );
        assert!(area > 0.0, "area {area}");
        assert!(mean_fg > thr, "foreground brighter than threshold");
        assert!(thr > 0.0 && thr < 1.0, "otsu in normalized range: {thr}");
    }
}

#[test]
fn nuclei_area_scales_with_density() {
    let Some(rt) = runtime() else { return };
    let mut gen = ImageGen::new(9, 128);
    let sparse = rt.analyze_image(&gen.generate(6)).unwrap();
    let dense = rt.analyze_image(&gen.generate(60)).unwrap();
    assert!(dense[1] > sparse[1], "dense {} vs sparse {}", dense[1], sparse[1]);
}

#[test]
fn nuclei_execution_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut gen = ImageGen::new(4, 128);
    let img = gen.generate(25);
    let a = rt.analyze_image(&img).unwrap();
    let b = rt.analyze_image(&img).unwrap();
    assert_eq!(a, b);
}

#[test]
fn nuclei_rejects_wrong_shape() {
    let Some(rt) = runtime() else { return };
    let bad = vec![0f32; 64 * 64];
    assert!(rt.analyze_image(&bad).is_err());
}

#[test]
fn busy_kernel_state_bounded_and_deterministic() {
    let Some(rt) = runtime() else { return };
    let exe = rt.get_kind("busy").unwrap();
    let n = exe.spec.inputs[0][0];
    let x: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let w: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.02).collect();
    let out1 = exe.run_f32(&[&x, &w]).unwrap();
    let out2 = exe.run_f32(&[&x, &w]).unwrap();
    assert_eq!(out1, out2, "deterministic");
    let y = &out1[0];
    assert_eq!(y.len(), n * n);
    assert!(y.iter().all(|v| v.is_finite()));
    assert!(y.iter().all(|v| v.abs() < 2.0), "tanh chain stays bounded");
    // And it actually computes something.
    assert!(y.iter().any(|v| v.abs() > 1e-3));
}

#[test]
fn busy_calibration_measures_wall_time() {
    let Some(rt) = runtime() else { return };
    let exe = rt.get_kind("busy").unwrap();
    let n = exe.spec.inputs[0][0];
    let mut state: Vec<f32> = vec![0.1; n * n];
    let w: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32 - 2.0) * 0.03).collect();
    let dt = rt.busy_units(3, &mut state, &w).unwrap();
    assert!(dt.as_nanos() > 0);
    assert!(state.iter().all(|v| v.is_finite()));
}

#[test]
fn artifact_variant_selected_by_image_size() {
    let Some(rt) = runtime() else { return };
    // 128² and 256² both dispatch to their compiled variant.
    let mut gen = ImageGen::new(11, 128);
    let small = rt.analyze_image(&gen.generate(15)).unwrap();
    let mut gen = ImageGen::new(11, 256);
    let large = rt.analyze_image(&gen.generate(15)).unwrap();
    for out in [small, large] {
        assert!(out[0] >= 7.0 && out[0] <= 25.0, "count {}", out[0]);
    }
    // Unknown size → clear error naming the available variants.
    let err = rt.analyze_image(&vec![0.0f32; 64 * 64]).unwrap_err();
    assert!(format!("{err:#}").contains("no nuclei artifact"), "{err:#}");
}
