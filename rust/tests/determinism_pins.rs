//! Determinism pins for the event-wheel simulator core (the PR 9 perf
//! work). The wheel core and the threaded shard fan-out are pure
//! performance features with a hard contract: byte-identical output to
//! the serial scan oracle. These pins enforce the contract at the
//! coarsest scope available — the full experiment registry at seed 42
//! and whole-cluster CSV fingerprints — so they double as the
//! no-toolchain CI fallback for `scripts/bench_check.sh` (which cannot
//! compare wall-clock numbers without cargo, but a future toolchain run
//! must find these pins green before trusting any speedup).

use std::collections::BTreeMap;
use std::path::Path;

use harmonicio::cloud::CloudConfig;
use harmonicio::experiments;
use harmonicio::sim::{set_default_event_core, Arrival, ClusterConfig, EventCore, SimCluster};
use harmonicio::types::{ImageName, Millis};
use harmonicio::worker::WorkerConfig;

/// Every file under `dir`, repo-relative path → bytes.
fn dir_contents(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, base: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir)
            .expect("readable output dir")
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, base, out);
            } else {
                let rel = p
                    .strip_prefix(base)
                    .expect("child of base")
                    .to_string_lossy()
                    .into_owned();
                out.insert(rel, std::fs::read(&p).expect("readable output file"));
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

/// Tentpole pin: the ENTIRE experiment registry (all 18 drivers, seed 42)
/// must produce byte-identical outputs — every per-experiment CSV and the
/// cumulative summary — under the wheel core and the legacy full-fleet
/// scan. The process-global default is flipped so the registry's internal
/// config constructors pick the core up without threading a flag through
/// every driver; both runs happen inside this single test, so no
/// concurrently running test ever observes the flipped default.
#[test]
fn full_experiment_registry_is_byte_identical_wheel_vs_scan() {
    let base = std::env::temp_dir().join("hio_pins_event_core");
    let scan_dir = base.join("scan");
    let wheel_dir = base.join("wheel");
    for d in [&scan_dir, &wheel_dir] {
        let _ = std::fs::remove_dir_all(d);
    }

    set_default_event_core(EventCore::Scan);
    let scan_reports =
        experiments::run("all", scan_dir.to_str().expect("utf-8 tmp path"), 42)
            .expect("scan-core suite runs");
    set_default_event_core(EventCore::Wheel);
    let wheel_reports =
        experiments::run("all", wheel_dir.to_str().expect("utf-8 tmp path"), 42)
            .expect("wheel-core suite runs");

    let scan_text: Vec<String> = scan_reports.iter().map(|r| r.render()).collect();
    let wheel_text: Vec<String> = wheel_reports.iter().map(|r| r.render()).collect();
    assert_eq!(scan_text, wheel_text, "report renders diverge between cores");
    assert_eq!(scan_reports.len(), 18, "the whole registry ran");

    let scan_files = dir_contents(&scan_dir);
    let wheel_files = dir_contents(&wheel_dir);
    let scan_names: Vec<&String> = scan_files.keys().collect();
    let wheel_names: Vec<&String> = wheel_files.keys().collect();
    assert_eq!(scan_names, wheel_names, "output file sets diverge between cores");
    assert!(
        scan_files.len() >= 10,
        "the registry wrote its per-experiment outputs ({} files)",
        scan_files.len()
    );
    for (name, bytes) in &scan_files {
        assert!(
            wheel_files.get(name) == Some(bytes),
            "{name} is not byte-identical between the wheel and scan cores"
        );
    }
}

/// Satellite pin: N data-independent shard packing sub-rounds executed on
/// std threads must be byte-identical to the serial sweep — at whole
/// cluster scope (recorder CSV, completion count, cost ledger, packing
/// work counters), not just per-update. Exercised at 4 shards with
/// serial, even (4) and non-dividing (3) thread counts, multi-stream so
/// every shard owns work. The event core is pinned explicitly (not via
/// the process-global, which another test in this binary flips).
#[test]
fn parallel_shard_ticks_match_serial_at_cluster_level() {
    let run = |parallel_workers: usize| {
        let mut cfg = ClusterConfig::default();
        cfg.event_core = EventCore::Wheel;
        cfg.cloud = CloudConfig {
            quota: 6,
            boot_delay: Millis::from_secs(5),
            boot_jitter: Millis(1000),
            ..CloudConfig::default()
        };
        cfg.worker = WorkerConfig {
            container_boot: Millis(2000),
            container_boot_jitter: Millis(500),
            container_idle_timeout: Millis::from_secs(5),
            image_pull: Millis::ZERO,
            measure_noise_std: 0.0,
            ..WorkerConfig::default()
        };
        cfg.irm.sharding.shards = 4;
        cfg.irm.sharding.parallel_workers = parallel_workers;
        let mut c = SimCluster::new(cfg);
        for img in ["stream-a", "stream-b", "stream-c", "stream-d", "stream-e"] {
            for i in 0u64..25 {
                c.schedule_arrival(
                    Millis((i % 7) * 1500),
                    Arrival {
                        image: ImageName::new(img),
                        payload_bytes: 1 << 20,
                        service_demand: Millis::from_secs(6),
                    },
                );
            }
        }
        c.run_until(Millis::from_secs(300));
        (
            c.recorder.to_csv(),
            c.completions.len(),
            format!("{:.12}", c.cloud.cost_usd()),
            c.sched_critical_work,
            c.sched_pack_work,
        )
    };
    let serial = run(0);
    assert!(serial.1 > 0, "the workload actually completed messages");
    let par4 = run(4);
    assert_eq!(serial.0, par4.0, "recorder CSV must be byte-identical (4 threads)");
    assert_eq!(serial, par4);
    let par3 = run(3);
    assert_eq!(serial, par3, "non-dividing thread count must merge identically");
}
