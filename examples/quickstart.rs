//! Quickstart: the whole three-layer stack in ~60 lines of user code.
//!
//! 1. boots the *live* HarmonicIO cluster (rust coordinator; PE threads
//!    each compile + run the AOT JAX/Pallas nuclei artifact via PJRT);
//! 2. streams a handful of synthetic fluorescence-microscopy images
//!    (large individual objects — the paper's workload class);
//! 3. prints the per-image analysis features and cluster statistics.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::workload::ImageGen;

fn main() -> anyhow::Result<()> {
    // --- 1. Boot the live cluster over the AOT artifacts. ---
    let mut cluster = LiveCluster::new(
        "artifacts",
        LiveConfig {
            max_pes: 4,
            initial_pes: 2,
            ..LiveConfig::default()
        },
    )?;
    println!(
        "HarmonicIO live cluster: platform={} PEs={}",
        cluster.platform(),
        cluster.pe_count()
    );

    // --- 2. Stream a small plate of images. ---
    let mut gen = ImageGen::new(42, 128);
    let plate = gen.plate(8);
    println!("streaming {} images (128x128 f32, Hoechst-like nuclei)", plate.len());
    for (_, pixels) in &plate {
        cluster.stream(pixels.clone());
    }

    // --- 3. Wait for results, print the analysis. ---
    cluster.drain_until(plate.len() as u64, std::time::Duration::from_secs(300))?;
    println!("\n  msg  planted  counted   area_px   otsu_thr");
    for r in &cluster.results {
        let planted = plate[r.id.0 as usize].0;
        println!(
            "  {:>3}  {:>7}  {:>7.0}  {:>8.0}  {:>9.3}",
            r.id.0, planted, r.features[0], r.features[1], r.features[3]
        );
    }
    let s = &cluster.stats;
    println!(
        "\ncompleted {} | mean service {:?} | mean latency {:?} | PEs peak {}",
        s.completed,
        s.mean_service(),
        s.mean_latency(),
        s.pes_peak
    );
    println!("quickstart OK");
    Ok(())
}
