//! The paper's §VI-A synthetic evaluation as a library consumer: build the
//! four busy-CPU workload classes, stream them (small regular batches +
//! two peaks) into a simulated HIO+IRM cluster, and render Figs 3–5.
//!
//! Run with: `cargo run --release --example synthetic_workloads [seed]`

use harmonicio::experiments::synthetic;
use harmonicio::types::Millis;
use harmonicio::workload::{SyntheticConfig, SyntheticWorkload};

fn main() -> anyhow::Result<()> {
    let seed: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);

    // Show the workload itself first.
    let wl = SyntheticWorkload::new(SyntheticConfig::default());
    let trace = wl.trace();
    println!(
        "synthetic trace: {} jobs over {:.0}s ({:.0} core-seconds total)",
        trace.len(),
        trace.end().as_secs_f64(),
        trace.total_demand().as_secs_f64()
    );

    // Run the full scenario and render each figure.
    let cluster = synthetic::run_scenario(seed);
    println!(
        "completed {} jobs, makespan {}",
        cluster.completions.len(),
        cluster
            .completions
            .iter()
            .map(|c| c.completed_at)
            .max()
            .unwrap_or(Millis::ZERO)
    );

    println!("\n--- Fig 3/4: measured vs scheduled CPU per worker ---");
    let names: Vec<String> = (0..cluster.max_worker_slots().min(4))
        .flat_map(|i| [format!("w{i}.measured"), format!("w{i}.scheduled")])
        .collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    println!("{}", cluster.recorder.ascii_chart(&refs, 76, 3));

    println!("--- Fig 5: error (pp) per worker ---");
    let err_names: Vec<String> = (0..cluster.max_worker_slots().min(3))
        .map(|i| format!("w{i}.error_pp"))
        .collect();
    let err_refs: Vec<&str> = err_names.iter().map(|s| s.as_str()).collect();
    println!("{}", cluster.recorder.ascii_chart(&err_refs, 76, 3));

    // Utilization summary (the Fig 4 claim: workers peak at 90-100 %).
    println!("worker peak / mean utilization:");
    for i in 0..cluster.max_worker_slots() {
        if let Some(s) = cluster.recorder.get(&format!("w{i}.measured")) {
            println!("  w{i}: peak {:>5.1}% mean {:>5.1}%", s.max() * 100.0, s.mean() * 100.0);
        }
    }
    println!("synthetic_workloads OK");
    Ok(())
}
