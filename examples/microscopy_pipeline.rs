//! End-to-end validation driver (DESIGN.md: the "real small workload"
//! example): the full microscopy pipeline across all three layers.
//!
//! Phase A (real compute): generate a plate of fluorescence images at the
//! paper's six seeding densities, stream them through the **live** cluster
//! (PE threads → PJRT → the AOT JAX/Pallas nuclei pipeline) and check the
//! counted nuclei track the planted densities. Reports latency/throughput.
//!
//! Phase B (cluster dynamics): the paper's full §VI-B protocol — the
//! 767-image collection on a simulated 5-worker HIO+IRM cluster, 10 runs
//! with profile carry-over — and renders Figs 8–10 shapes.
//!
//! Run with: `make artifacts && cargo run --release --example microscopy_pipeline`

use harmonicio::experiments::microscopy;
use harmonicio::master::{LiveCluster, LiveConfig};
use harmonicio::workload::{imagegen::SEEDING_DENSITIES, ImageGen};

fn main() -> anyhow::Result<()> {
    // ---------- Phase A: real PJRT compute ----------
    println!("=== Phase A: live PJRT nuclei analysis ===");
    let mut cluster = LiveCluster::new(
        "artifacts",
        LiveConfig {
            max_pes: 4,
            initial_pes: 2,
            ..LiveConfig::default()
        },
    )?;
    let n_images = 24usize;
    let mut gen = ImageGen::new(2020, 128);
    let plate = gen.plate(n_images);
    let t0 = std::time::Instant::now();
    for (_, pixels) in &plate {
        cluster.stream(pixels.clone());
    }
    cluster.drain_until(n_images as u64, std::time::Duration::from_secs(600))?;
    let wall = t0.elapsed();

    // Per-density accuracy: counted vs planted.
    println!("density  planted  mean_counted  images");
    let mut ok_densities = 0;
    for &density in &SEEDING_DENSITIES {
        let counts: Vec<f32> = cluster
            .results
            .iter()
            .filter(|r| plate[r.id.0 as usize].0 == density)
            .map(|r| r.features[0])
            .collect();
        let mean = counts.iter().sum::<f32>() / counts.len().max(1) as f32;
        let ok = mean >= density as f32 * 0.5 && mean <= density as f32 * 1.5 + 2.0;
        if ok {
            ok_densities += 1;
        }
        println!(
            "{:>7}  {:>7}  {:>12.1}  {:>6}  {}",
            density,
            density,
            mean,
            counts.len(),
            if ok { "ok" } else { "OFF" }
        );
    }
    let s = &cluster.stats;
    println!(
        "throughput {:.2} img/s | mean service {:?} | mean cpu/job {:?} | latency {:?}",
        s.completed as f64 / wall.as_secs_f64(),
        s.mean_service(),
        s.total_cpu / s.completed.max(1) as u32,
        s.mean_latency()
    );
    anyhow::ensure!(
        ok_densities >= 5,
        "nuclei counts should track planted densities ({ok_densities}/6 ok)"
    );

    // ---------- Phase B: the paper's cluster protocol ----------
    println!("\n=== Phase B: §VI-B 10-run protocol on the simulated cluster ===");
    let runs = microscopy::ten_runs(42, 10);
    println!(
        "makespans (s): {}",
        runs.makespans
            .iter()
            .map(|m| format!("{:.0}", m.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(", ")
    );
    let last = &runs.last;
    println!("\n--- Fig 8 shape: scheduled CPU per worker (run 10) ---");
    let names: Vec<String> = (0..5).map(|i| format!("w{i}.scheduled")).collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    println!("{}", last.recorder.ascii_chart(&refs, 76, 3));
    println!("--- Fig 10 shape: workers current/target + active bins ---");
    println!(
        "{}",
        last.recorder
            .ascii_chart(&["workers.current", "workers.target", "bins.active"], 76, 4)
    );
    println!(
        "rejected VM requests (quota retries): {}",
        last.cloud.rejected_requests
    );
    anyhow::ensure!(runs.last.completions.len() == 767, "all images processed");
    println!("microscopy_pipeline OK");
    Ok(())
}
