//! The headline comparison as a runnable scenario: Spark Streaming with
//! dynamic allocation vs HIO+IRM on the same 767-image batch (paper §VI-B,
//! Figs 7 vs 8, "execution time of the entire batch of images is nearly
//! halved").
//!
//! Run with: `cargo run --release --example spark_comparison [seed]`

use harmonicio::experiments::{microscopy, spark_fig7};

fn main() -> anyhow::Result<()> {
    let seed: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);

    println!("=== Spark Streaming baseline (Fig 7) ===");
    let (spark, spark_makespan) = spark_fig7::run_baseline(seed);
    println!(
        "{}",
        spark
            .recorder
            .ascii_chart(&["spark.executor_cores", "spark.cpu_cores"], 76, 5)
    );
    println!(
        "spark: {} tasks, makespan {:.0}s, {} idle-gap scale-downs",
        spark.tasks_completed,
        spark_makespan.as_secs_f64(),
        spark.scale_downs.len()
    );

    println!("\n=== HIO + IRM on the same trace ===");
    let runs = microscopy::ten_runs(seed, 3);
    let hio = runs.makespans.last().unwrap().as_secs_f64();
    println!(
        "hio: 767 images, makespans {:?}s",
        runs.makespans
            .iter()
            .map(|m| m.as_secs_f64().round())
            .collect::<Vec<_>>()
    );
    let names: Vec<String> = (0..5).map(|i| format!("w{i}.measured")).collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    println!("{}", runs.last.recorder.ascii_chart(&refs, 76, 3));

    let ratio = spark_makespan.as_secs_f64() / hio;
    println!(
        "\nheadline: Spark {:.0}s vs HIO {:.0}s → {ratio:.2}x (paper: ≈2x, \"nearly halved\")",
        spark_makespan.as_secs_f64(),
        hio
    );
    anyhow::ensure!(ratio > 1.2, "HIO must win decisively (got {ratio:.2}x)");
    println!("spark_comparison OK");
    Ok(())
}
